//! Chargax reproduction: a three-layer Rust + JAX + Pallas system.
//!
//! Layer 3 (this crate) is the coordinator: it loads AOT-compiled XLA
//! programs (HLO text produced by `python/compile/aot.py`) through the PJRT
//! C API and drives training, evaluation, and the paper's benchmark suite.
//! Python is never on the hot path.
//!
//! Module map (see DESIGN.md for the full inventory):
//! * [`runtime`] — manifest + PJRT engine + tensor/literal bridge,
//! * [`coordinator`] — train/eval sessions and the training driver,
//! * [`data`] — exogenous tables (prices, cars, arrivals, profiles),
//! * [`env`] — pure-Rust simulators over one shared transition core: the
//!   SoA batched `VectorEnv` fast path + the per-step `ScalarEnv` comparator,
//! * [`fleet`] — scenario catalog + heterogeneous multi-station scheduling:
//!   N different `StationConfig`s (incl. V2G) on one worker pool, with a
//!   fused cross-env rollout and per-family PPO,
//! * [`baselines`] — pure-Rust PPO + heuristic policies (CPU comparators),
//! * [`config`] — experiment configuration,
//! * [`telemetry`] — zero-overhead span tracing, typed counters, and the
//!   pool-utilization profiler (per-iteration reports, JSONL run logs,
//!   Chrome trace export),
//! * [`util`] — in-tree JSON / RNG / bench-stat / property-test substrates.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod env;
pub mod fleet;
pub mod runtime;
pub mod telemetry;
pub mod util;
