//! Exogenous data tables (paper Table 1), loaded from `artifacts/data/*.json`
//! (exported by python/compile/data.py so both simulators see bit-identical
//! values).
//!
//! `ExogBundle` assembles the 12 exogenous leaves in the exact order of
//! `ExogData` on the Python side; the manifest's input specs validate the
//! shapes at session build time.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::Tensor;
use crate::util::json::Json;

pub const PENALTIES: [&str; 7] = [
    "constraint",
    "satisfaction0",
    "satisfaction1",
    "sustain",
    "declined",
    "degradation",
    "grid",
];

pub const SCENARIOS: [&str; 4] = ["shopping", "work", "residential", "highway"];
pub const REGIONS: [&str; 3] = ["EU", "US", "WORLD"];
pub const COUNTRIES: [&str; 3] = ["NL", "FR", "DE"];
pub const YEARS: [u32; 3] = [2021, 2022, 2023];
pub const USER_PROFILE_FIELDS: [&str; 6] = [
    "stay_mean_h", "stay_std_h", "soc0_a", "soc0_b", "target_soc", "p_time_sensitive",
];

#[derive(Debug, Clone)]
pub struct DataStore {
    /// "NL_2021" -> flat [days*24] EUR/kWh.
    pub prices: BTreeMap<String, Vec<f32>>,
    pub n_days: usize,
    pub moer: Vec<f32>,                        // [days*24]
    pub car_table: Vec<f32>,                   // [n_models*4]
    pub n_models: usize,
    pub car_weights: BTreeMap<String, Vec<f32>>,
    pub car_names: Vec<String>,
    pub arrival_shapes: BTreeMap<String, Vec<f32>>, // [24] each
    pub traffic: BTreeMap<String, f32>,
    pub user_profiles: BTreeMap<String, Vec<f32>>, // [6] each
}

impl DataStore {
    pub fn load(data_dir: &Path) -> Result<DataStore> {
        let read = |name: &str| -> Result<Json> {
            let p = data_dir.join(name);
            let text = std::fs::read_to_string(&p)
                .with_context(|| format!("reading {} (run `make artifacts`)", p.display()))?;
            Json::parse(&text).with_context(|| format!("parsing {name}"))
        };

        // prices.json
        let pj = read("prices.json")?;
        let mut prices = BTreeMap::new();
        let mut n_days = 0usize;
        for (k, v) in pj.get("tables").and_then(Json::as_obj).context("prices.tables")? {
            let rows = v.as_arr().context("price table")?;
            n_days = rows.len();
            prices.insert(k.clone(), v.as_f32_flat().context("price values")?);
        }

        // moer.json
        let mj = read("moer.json")?;
        let moer = mj.get("table").and_then(Json::as_f32_flat).context("moer.table")?;

        // cars.json
        let cj = read("cars.json")?;
        let catalog = cj.get("catalog").and_then(Json::as_arr).context("cars.catalog")?;
        let n_models = catalog.len();
        let mut car_table = Vec::with_capacity(n_models * 4);
        let mut car_names = Vec::with_capacity(n_models);
        for m in catalog {
            car_names.push(m.get("name").and_then(Json::as_str).context("car name")?.to_string());
            for f in ["cap", "ac", "dc", "tau"] {
                car_table.push(m.get(f).and_then(Json::as_f64).context("car col")? as f32);
            }
        }
        let mut car_weights = BTreeMap::new();
        for (r, w) in cj.get("weights").and_then(Json::as_obj).context("cars.weights")? {
            car_weights.insert(r.clone(), w.as_f32_flat().context("weights")?);
        }

        // arrivals.json
        let aj = read("arrivals.json")?;
        let mut arrival_shapes = BTreeMap::new();
        for (s, v) in aj.get("shapes").and_then(Json::as_obj).context("arrivals.shapes")? {
            arrival_shapes.insert(s.clone(), v.as_f32_flat().context("shape")?);
        }
        let mut traffic = BTreeMap::new();
        for (k, v) in aj
            .get("traffic_multipliers")
            .and_then(Json::as_obj)
            .context("traffic_multipliers")?
        {
            traffic.insert(k.clone(), v.as_f64().context("traffic")? as f32);
        }

        // user_profiles.json
        let uj = read("user_profiles.json")?;
        let fields = uj.get("fields").and_then(Json::as_str_vec).context("fields")?;
        if fields != USER_PROFILE_FIELDS {
            bail!("user profile field order drifted: {fields:?}");
        }
        let mut user_profiles = BTreeMap::new();
        for (s, p) in uj.get("profiles").and_then(Json::as_obj).context("profiles")? {
            let vec: Vec<f32> = USER_PROFILE_FIELDS
                .iter()
                .map(|f| {
                    p.get(f)
                        .and_then(Json::as_f64)
                        .map(|x| x as f32)
                        .context(format!("profile field {f}"))
                })
                .collect::<Result<_>>()?;
            user_profiles.insert(s.clone(), vec);
        }

        Ok(DataStore {
            prices,
            n_days,
            moer,
            car_table,
            n_models,
            car_weights,
            car_names,
            arrival_shapes,
            traffic,
            user_profiles,
        })
    }

    pub fn price(&self, country: &str, year: u32) -> Result<&Vec<f32>> {
        self.prices
            .get(&format!("{country}_{year}"))
            .ok_or_else(|| anyhow!("no price table {country}_{year}"))
    }
}

/// A fully-specified exogenous scenario (what the paper calls a
/// "bundled scenario" + reward weighting).
#[derive(Debug, Clone)]
pub struct Scenario {
    pub scenario: String, // shopping | work | residential | highway
    pub region: String,   // EU | US | WORLD
    pub country: String,  // NL | FR | DE
    pub year: u32,        // 2021..2023
    pub traffic: String,  // low | medium | high
    pub alpha: [f32; 7],
    pub beta: f32,
    pub p_sell: f32,
    pub feed_in_ratio: f32,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            scenario: "shopping".into(),
            region: "EU".into(),
            country: "NL".into(),
            year: 2021,
            traffic: "medium".into(),
            alpha: [0.0; 7],
            beta: 0.1,
            p_sell: 0.75,
            feed_in_ratio: 0.9,
        }
    }
}

impl Scenario {
    pub fn with_alpha(mut self, name: &str, value: f32) -> Result<Self> {
        let i = PENALTIES
            .iter()
            .position(|p| *p == name)
            .ok_or_else(|| anyhow!("unknown penalty '{name}' (have {PENALTIES:?})"))?;
        self.alpha[i] = value;
        Ok(self)
    }

    /// Build the 12 exogenous leaves in ExogData field order.
    pub fn to_tensors(&self, store: &DataStore) -> Result<Vec<Tensor>> {
        let d = store.n_days;
        let buy = store.price(&self.country, self.year)?.clone();
        let sell_grid: Vec<f32> = buy.iter().map(|x| x * self.feed_in_ratio).collect();
        let mean_buy =
            (buy.iter().map(|x| *x as f64).sum::<f64>() / buy.len() as f64).max(1e-6) as f32;
        let grid_demand: Vec<f32> = buy.iter().map(|x| (x / mean_buy - 1.0) * 5.0).collect();
        let arrival = store
            .arrival_shapes
            .get(&self.scenario)
            .ok_or_else(|| anyhow!("unknown scenario '{}'", self.scenario))?
            .clone();
        let weights = store
            .car_weights
            .get(&self.region)
            .ok_or_else(|| anyhow!("unknown region '{}'", self.region))?
            .clone();
        let profile = store
            .user_profiles
            .get(&self.scenario)
            .ok_or_else(|| anyhow!("no user profile for '{}'", self.scenario))?
            .clone();
        let traffic = *store
            .traffic
            .get(&self.traffic)
            .ok_or_else(|| anyhow!("unknown traffic level '{}'", self.traffic))?;

        Ok(vec![
            Tensor::f32(vec![d, 24], buy)?,
            Tensor::f32(vec![d, 24], sell_grid)?,
            Tensor::f32(vec![d, 24], store.moer.clone())?,
            Tensor::f32(vec![d, 24], grid_demand)?,
            Tensor::f32(vec![24], arrival)?,
            Tensor::f32(vec![store.n_models, 4], store.car_table.clone())?,
            Tensor::f32(vec![store.n_models], normalized(&weights))?,
            Tensor::f32(vec![6], profile)?,
            Tensor::f32(vec![7], self.alpha.to_vec())?,
            Tensor::scalar_f32(self.p_sell),
            Tensor::scalar_f32(traffic),
            Tensor::scalar_f32(self.beta),
        ])
    }
}

fn normalized(w: &[f32]) -> Vec<f32> {
    let s: f32 = w.iter().sum();
    w.iter().map(|x| x / s.max(1e-12)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_alpha_by_name() {
        let s = Scenario::default()
            .with_alpha("satisfaction0", 2.0)
            .unwrap();
        assert_eq!(s.alpha[1], 2.0);
        assert!(Scenario::default().with_alpha("nope", 1.0).is_err());
    }
}
