//! Scenario catalog: a declarative grid of charging-station scenarios
//! with deterministic seeded expansion into per-lane assignments.
//!
//! A [`ScenarioSpec`] names the grid axes the paper varies — country ×
//! price-year × traffic × user-profile — plus a station layout and a
//! `v2g` flag, and how many env lanes to allocate to the entry. A
//! [`FleetSpec`] bundles several entries; [`expand`] turns it into
//! per-family lane plans: lanes with the same `StationConfig` (hence the
//! same obs/action space) land in one family, the cell order inside each
//! entry is shuffled with a seeded [`CounterRng`] and lanes round-robin
//! over it, and every scenario's tables are built once through the
//! [`TableCache`] — lanes sharing a scenario share one
//! `Arc<ScenarioTables>` instead of each caller hand-building per-lane
//! table vectors.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::{DataStore, Scenario};
use crate::env::core::ScenarioTables;
use crate::env::tree::StationConfig;
use crate::util::json::Json;
use crate::util::rng::CounterRng;

/// Station-layout axis of the grid: the electrical shape of one family.
/// Everything not listed here keeps the paper's Table 3 defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct StationLayout {
    pub n_dc: usize,
    pub n_ac: usize,
    pub battery_capacity_kwh: f32,
    pub battery_p_max_kw: f32,
}

impl Default for StationLayout {
    fn default() -> Self {
        let d = StationConfig::default();
        StationLayout {
            n_dc: d.n_dc,
            n_ac: d.n_ac,
            battery_capacity_kwh: d.battery_capacity_kwh,
            battery_p_max_kw: d.battery_p_max_kw,
        }
    }
}

impl StationLayout {
    /// Concrete station config for this layout (+ the entry's V2G flag).
    pub fn station_config(&self, v2g: bool) -> StationConfig {
        StationConfig {
            n_dc: self.n_dc,
            n_ac: self.n_ac,
            battery_capacity_kwh: self.battery_capacity_kwh,
            battery_p_max_kw: self.battery_p_max_kw,
            v2g,
            ..StationConfig::default()
        }
    }

    fn from_json(j: &Json) -> Result<StationLayout> {
        let d = StationLayout::default();
        let num = |key: &str, dflt: f32| -> Result<f32> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => v
                    .as_f64()
                    .map(|x| x as f32)
                    .ok_or_else(|| anyhow!("layout field \"{key}\" must be a number")),
            }
        };
        let count = |key: &str, dflt: usize| -> Result<usize> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| anyhow!("layout field \"{key}\" must be a count")),
            }
        };
        Ok(StationLayout {
            n_dc: count("n_dc", d.n_dc)?,
            n_ac: count("n_ac", d.n_ac)?,
            battery_capacity_kwh: num("battery_capacity_kwh", d.battery_capacity_kwh)?,
            battery_p_max_kw: num("battery_p_max_kw", d.battery_p_max_kw)?,
        })
    }
}

/// One grid entry: `lanes` env lanes spread over the cross product
/// country × year × traffic × profile, on one station layout, optionally
/// V2G-enabled.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub lanes: usize,
    pub countries: Vec<String>,
    pub years: Vec<u32>,
    pub traffics: Vec<String>,
    /// Arrival/user-profile scenario names (the paper's bundled
    /// scenarios: shopping | work | residential | highway).
    pub profiles: Vec<String>,
    /// Car-catalog region used when artifacts are available.
    pub region: String,
    pub layout: StationLayout,
    pub v2g: bool,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            name: "spec".into(),
            lanes: 4,
            countries: vec!["NL".into()],
            years: vec![2021],
            traffics: vec!["medium".into()],
            profiles: vec!["shopping".into()],
            region: "EU".into(),
            layout: StationLayout::default(),
            v2g: false,
        }
    }
}

impl ScenarioSpec {
    /// Cross product of the grid axes as fully-specified scenarios.
    pub fn cells(&self) -> Vec<Scenario> {
        let mut out = Vec::new();
        for profile in &self.profiles {
            for country in &self.countries {
                for &year in &self.years {
                    for traffic in &self.traffics {
                        out.push(Scenario {
                            scenario: profile.clone(),
                            region: self.region.clone(),
                            country: country.clone(),
                            year,
                            traffic: traffic.clone(),
                            ..Scenario::default()
                        });
                    }
                }
            }
        }
        out
    }

    fn from_json(j: &Json) -> Result<ScenarioSpec> {
        let d = ScenarioSpec::default();
        let str_list = |key: &str, dflt: Vec<String>| -> Result<Vec<String>> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => v
                    .as_str_vec()
                    .ok_or_else(|| anyhow!("\"{key}\" must be an array of strings")),
            }
        };
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("fleet entry needs a \"name\""))?;
        let lanes = j
            .get("lanes")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("fleet entry '{name}' needs a \"lanes\" count"))?;
        let years = match j.get("years") {
            None => d.years,
            Some(v) => v
                .as_arr()
                .ok_or_else(|| anyhow!("\"years\" must be an array"))?
                .iter()
                .map(|y| {
                    y.as_f64()
                        .map(|x| x as u32)
                        .ok_or_else(|| anyhow!("\"years\" entries must be numbers"))
                })
                .collect::<Result<_>>()?,
        };
        let layout = match j.get("layout") {
            None => d.layout,
            Some(l) => StationLayout::from_json(l)
                .with_context(|| format!("fleet entry '{name}' layout"))?,
        };
        Ok(ScenarioSpec {
            lanes,
            countries: str_list("countries", d.countries)?,
            years,
            traffics: str_list("traffics", d.traffics)?,
            profiles: str_list("profiles", d.profiles)?,
            region: j
                .get("region")
                .and_then(Json::as_str)
                .unwrap_or(&d.region)
                .to_string(),
            layout,
            v2g: j.get("v2g").and_then(Json::as_bool).unwrap_or(false),
            name,
        })
    }
}

/// A whole fleet: several grid entries plus the expansion seed.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub seed: u64,
    pub specs: Vec<ScenarioSpec>,
}

impl FleetSpec {
    /// Built-in demo fleet: three structurally different station families
    /// — the paper's mixed AC/DC station over a 4-cell scenario grid, a
    /// DC-fast V2G plaza, and an AC-only battery-less lot. `lanes_scale`
    /// multiplies every entry's lane count (bench sweeps drive it).
    pub fn demo(seed: u64, lanes_scale: usize) -> FleetSpec {
        let k = lanes_scale.max(1);
        FleetSpec {
            seed,
            specs: vec![
                ScenarioSpec {
                    name: "mixed-ac-dc".into(),
                    lanes: 8 * k,
                    years: vec![2021, 2022],
                    traffics: vec!["medium".into(), "high".into()],
                    ..ScenarioSpec::default()
                },
                ScenarioSpec {
                    name: "dc-plaza-v2g".into(),
                    lanes: 8 * k,
                    profiles: vec!["work".into()],
                    layout: StationLayout { n_dc: 8, n_ac: 0, ..StationLayout::default() },
                    v2g: true,
                    ..ScenarioSpec::default()
                },
                ScenarioSpec {
                    name: "ac-lot".into(),
                    lanes: 4 * k,
                    traffics: vec!["low".into()],
                    layout: StationLayout {
                        n_dc: 0,
                        n_ac: 8,
                        battery_capacity_kwh: 0.0,
                        battery_p_max_kw: 0.0,
                    },
                    ..ScenarioSpec::default()
                },
            ],
        }
    }

    pub fn from_json_file(path: &str) -> Result<FleetSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fleet spec {path}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing fleet spec {path}"))?;
        FleetSpec::from_json(&j).with_context(|| format!("fleet spec {path}"))
    }

    /// Schema (README §Scenario fleets & V2G):
    /// `{"seed": N, "fleet": [{"name", "lanes", "countries", "years",
    /// "traffics", "profiles", "region", "layout": {...}, "v2g"}, ...]}`.
    pub fn from_json(j: &Json) -> Result<FleetSpec> {
        let seed = j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let entries = j
            .get("fleet")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("fleet spec needs a top-level \"fleet\" array"))?;
        let mut specs = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            specs.push(ScenarioSpec::from_json(e).with_context(|| format!("fleet entry {i}"))?);
        }
        Ok(FleetSpec { seed, specs })
    }
}

/// Dedup cache: scenarios whose resolved tables would be identical share
/// one `Arc<ScenarioTables>` — built once, never cloned per lane.
#[derive(Default)]
pub struct TableCache {
    map: BTreeMap<String, Arc<ScenarioTables>>,
}

impl TableCache {
    pub fn new() -> TableCache {
        TableCache::default()
    }

    /// Cache key: every `Scenario` field that influences table contents
    /// (float fields keyed by bit pattern, so -0.0 vs 0.0 is the only
    /// equal-but-distinct case — harmless for a cache).
    fn key(sc: &Scenario) -> String {
        format!(
            "{}|{}|{}|{}|{}|{:?}|{}|{}|{}",
            sc.scenario,
            sc.region,
            sc.country,
            sc.year,
            sc.traffic,
            sc.alpha.map(f32::to_bits),
            sc.beta.to_bits(),
            sc.p_sell.to_bits(),
            sc.feed_in_ratio.to_bits(),
        )
    }

    pub fn get(&mut self, store: Option<&DataStore>, sc: &Scenario) -> Result<Arc<ScenarioTables>> {
        let key = Self::key(sc);
        if let Some(t) = self.map.get(&key) {
            return Ok(Arc::clone(t));
        }
        let tables = match store {
            Some(s) => {
                check_scenario_known(s, sc)?;
                ScenarioTables::build(s, sc).with_context(|| {
                    format!(
                        "building tables for scenario {} {} {}/{} traffic={}",
                        sc.scenario, sc.region, sc.country, sc.year, sc.traffic
                    )
                })?
            }
            None => ScenarioTables::synthetic_for(sc),
        };
        let arc = Arc::new(tables);
        self.map.insert(key, Arc::clone(&arc));
        Ok(arc)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Pre-flight the data-store lookups `ScenarioTables::build` performs
/// with panicking `BTreeMap` indexing, so a typo'd fleet entry fails
/// with the bad key (and the known ones) named instead of an opaque
/// `key not found` panic. (Without artifacts, synthetic tables accept
/// any names.)
fn check_scenario_known(store: &DataStore, sc: &Scenario) -> Result<()> {
    if !store.arrival_shapes.contains_key(&sc.scenario)
        || !store.user_profiles.contains_key(&sc.scenario)
    {
        bail!(
            "unknown profile/scenario '{}' (have {:?})",
            sc.scenario,
            store.arrival_shapes.keys().collect::<Vec<_>>()
        );
    }
    if !store.car_weights.contains_key(&sc.region) {
        bail!(
            "unknown region '{}' (have {:?})",
            sc.region,
            store.car_weights.keys().collect::<Vec<_>>()
        );
    }
    if !store.traffic.contains_key(&sc.traffic) {
        bail!(
            "unknown traffic level '{}' (have {:?})",
            sc.traffic,
            store.traffic.keys().collect::<Vec<_>>()
        );
    }
    store.price(&sc.country, sc.year).map(|_| ())
}

/// Human-readable name of one scenario cell (profile/country/year/traffic)
/// — what per-cell eval reporting prints next to each number.
pub fn cell_name(sc: &Scenario) -> String {
    format!("{}/{}/{}/{}", sc.scenario, sc.country, sc.year, sc.traffic)
}

/// One station family: every lane whose `StationConfig` (hence obs and
/// action space) is identical, ready to back one `VectorEnv`.
/// `cell_names[i]` names the scenario cell behind `tables[i]`.
pub struct FamilyPlan {
    pub label: String,
    pub cfg: StationConfig,
    pub tables: Vec<Arc<ScenarioTables>>,
    pub cell_names: Vec<String>,
    pub lane_scenario: Vec<usize>,
    pub seeds: Vec<u64>,
}

/// Expand a [`FleetSpec`] into per-family lane plans.
///
/// Deterministic and seeded: the cell order inside each entry is shuffled
/// with a `CounterRng` derived from `(fleet.seed, entry index)` and lanes
/// round-robin over the shuffled order (every cell is visited before any
/// repeats); per-lane RNG seeds come from one derived seeder stream, so
/// they are stable regardless of how entries regroup into families.
pub fn expand(fleet: &FleetSpec, store: Option<&DataStore>) -> Result<Vec<FamilyPlan>> {
    if fleet.specs.is_empty() {
        bail!("fleet spec has no scenario entries");
    }
    let mut cache = TableCache::new();
    let mut families: Vec<FamilyPlan> = Vec::new();
    let mut seeder = CounterRng::derive(fleet.seed, 0xF1EE7);
    for (s_idx, spec) in fleet.specs.iter().enumerate() {
        if spec.lanes == 0 {
            bail!("fleet entry '{}' has zero lanes", spec.name);
        }
        let cells = spec.cells();
        if cells.is_empty() {
            bail!(
                "fleet entry '{}' expands to an empty grid \
                 (check countries/years/traffics/profiles)",
                spec.name
            );
        }
        let cfg = spec.layout.station_config(spec.v2g);
        cfg.validate()
            .with_context(|| format!("fleet entry '{}' layout", spec.name))?;
        let mut order: Vec<usize> = (0..cells.len()).collect();
        let mut rng = CounterRng::derive(fleet.seed, s_idx as u64 + 1);
        for i in (1..order.len()).rev() {
            let j = rng.below(i as u32 + 1) as usize;
            order.swap(i, j);
        }
        let fam_idx = match families.iter().position(|f| f.cfg == cfg) {
            Some(i) => {
                families[i].label.push('+');
                families[i].label.push_str(&spec.name);
                i
            }
            None => {
                families.push(FamilyPlan {
                    label: spec.name.clone(),
                    cfg: cfg.clone(),
                    tables: Vec::new(),
                    cell_names: Vec::new(),
                    lane_scenario: Vec::new(),
                    seeds: Vec::new(),
                });
                families.len() - 1
            }
        };
        let fam = &mut families[fam_idx];
        for lane in 0..spec.lanes {
            let sc = &cells[order[lane % cells.len()]];
            let table = cache
                .get(store, sc)
                .with_context(|| format!("fleet entry '{}'", spec.name))?;
            let t_idx = match fam.tables.iter().position(|t| Arc::ptr_eq(t, &table)) {
                Some(i) => i,
                None => {
                    fam.tables.push(Arc::clone(&table));
                    fam.cell_names.push(cell_name(sc));
                    fam.tables.len() - 1
                }
            };
            fam.lane_scenario.push(t_idx);
            fam.seeds.push(seeder.next_u64());
        }
    }
    Ok(families)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_expands_to_three_heterogeneous_families() {
        let spec = FleetSpec::demo(7, 1);
        let fams = expand(&spec, None).unwrap();
        assert_eq!(fams.len(), 3);
        // Structurally different: distinct obs/action spaces.
        let dims: Vec<usize> = fams
            .iter()
            .map(|f| crate::env::core::obs_dim(&f.cfg))
            .collect();
        assert_ne!(dims[0], dims[1]);
        assert!(fams.iter().any(|f| f.cfg.v2g), "demo must include a V2G family");
        assert!(
            fams.iter().any(|f| f.cfg.battery_capacity_kwh == 0.0),
            "demo must include a battery-less family"
        );
        for f in &fams {
            assert_eq!(f.lane_scenario.len(), f.seeds.len());
            assert!(!f.tables.is_empty());
            assert!(f.lane_scenario.iter().all(|&i| i < f.tables.len()));
            // One name per distinct cell, all distinct within a family.
            assert_eq!(f.tables.len(), f.cell_names.len());
            for (i, a) in f.cell_names.iter().enumerate() {
                assert!(!a.is_empty());
                for b in &f.cell_names[i + 1..] {
                    assert_ne!(a, b, "duplicate cell name in family {}", f.label);
                }
            }
        }
    }

    #[test]
    fn expansion_is_deterministic_and_seed_sensitive() {
        let a = expand(&FleetSpec::demo(7, 1), None).unwrap();
        let b = expand(&FleetSpec::demo(7, 1), None).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.lane_scenario, y.lane_scenario);
            assert_eq!(x.seeds, y.seeds);
        }
        let c = expand(&FleetSpec::demo(8, 1), None).unwrap();
        assert_ne!(a[0].seeds, c[0].seeds, "different fleet seed, same lane seeds");
    }

    #[test]
    fn cache_dedups_repeated_scenarios() {
        // 8 lanes over a 4-cell grid: exactly 4 tables built, lanes
        // sharing a cell share the same Arc.
        let spec = FleetSpec {
            seed: 3,
            specs: vec![ScenarioSpec {
                lanes: 8,
                years: vec![2021, 2022],
                traffics: vec!["medium".into(), "high".into()],
                ..ScenarioSpec::default()
            }],
        };
        let fams = expand(&spec, None).unwrap();
        assert_eq!(fams.len(), 1);
        let f = &fams[0];
        assert_eq!(f.tables.len(), 4, "one shared table per distinct cell");
        assert_eq!(f.lane_scenario.len(), 8);
        // Round-robin over the shuffled order: each cell used twice.
        let mut counts = vec![0usize; f.tables.len()];
        for &i in &f.lane_scenario {
            counts[i] += 1;
        }
        assert!(counts.iter().all(|&c| c == 2), "cells unevenly covered: {counts:?}");
    }

    #[test]
    fn same_layout_entries_merge_into_one_family() {
        let mut a = ScenarioSpec { name: "a".into(), lanes: 3, ..ScenarioSpec::default() };
        a.traffics = vec!["low".into()];
        let b = ScenarioSpec { name: "b".into(), lanes: 2, ..ScenarioSpec::default() };
        let fams = expand(&FleetSpec { seed: 1, specs: vec![a, b] }, None).unwrap();
        assert_eq!(fams.len(), 1);
        assert_eq!(fams[0].lane_scenario.len(), 5);
        assert_eq!(fams[0].label, "a+b");
    }

    #[test]
    fn json_round_trip_parses_schema() {
        let text = r#"{
            "seed": 11,
            "fleet": [
                {"name": "nl", "lanes": 6, "countries": ["NL"],
                 "years": [2021, 2023], "traffics": ["low", "high"],
                 "profiles": ["shopping"],
                 "layout": {"n_dc": 4, "n_ac": 2}, "v2g": true}
            ]
        }"#;
        let spec = FleetSpec::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(spec.seed, 11);
        assert_eq!(spec.specs.len(), 1);
        let s = &spec.specs[0];
        assert_eq!(s.lanes, 6);
        assert_eq!(s.years, vec![2021, 2023]);
        assert_eq!(s.layout.n_dc, 4);
        assert!(s.v2g);
        assert_eq!(s.cells().len(), 4);
        // missing required fields error with the entry named
        let bad = r#"{"fleet": [{"name": "x"}]}"#;
        let err = FleetSpec::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("lanes"), "{err:#}");
    }
}
