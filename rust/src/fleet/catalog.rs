//! Scenario catalog: a declarative grid of charging-station scenarios
//! with deterministic seeded expansion into per-lane assignments.
//!
//! A [`ScenarioSpec`] names the grid axes the paper varies — country ×
//! price-year × traffic × user-profile — plus a station layout and a
//! `v2g` flag, and how many env lanes to allocate to the entry. A
//! [`FleetSpec`] bundles several entries; [`expand`] turns it into
//! per-family lane plans: lanes with the same `StationConfig` (hence the
//! same obs/action space) land in one family, the cell order inside each
//! entry is shuffled with a seeded [`CounterRng`] and lanes round-robin
//! over it, and every scenario's tables are built once through the
//! [`TableCache`] — lanes sharing a scenario share one
//! `Arc<ScenarioTables>` instead of each caller hand-building per-lane
//! table vectors.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::{DataStore, Scenario};
use crate::env::core::{self, ScenarioTables};
use crate::env::tree::StationConfig;
use crate::util::json::Json;
use crate::util::rng::CounterRng;

use super::grid::{CurtailPolicy, GridSpec};

/// Reject unknown keys in a spec object with a named error. A typo'd
/// `holdout`/`grid`/axis key used to be silently ignored — dropping the
/// constraint the author thought they expressed — so every schema object
/// now enumerates its legal keys and fails loudly on anything else.
fn reject_unknown_keys(j: &Json, allowed: &[&str], what: &str) -> Result<()> {
    let Some(map) = j.as_obj() else {
        bail!("{what} must be a JSON object");
    };
    for key in map.keys() {
        if !allowed.contains(&key.as_str()) {
            bail!("{what}: unknown key \"{key}\" (allowed: {})", allowed.join(", "));
        }
    }
    Ok(())
}

/// Station-layout axis of the grid: the electrical shape of one family.
/// Everything not listed here keeps the paper's Table 3 defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct StationLayout {
    pub n_dc: usize,
    pub n_ac: usize,
    pub battery_capacity_kwh: f32,
    pub battery_p_max_kw: f32,
}

impl Default for StationLayout {
    fn default() -> Self {
        let d = StationConfig::default();
        StationLayout {
            n_dc: d.n_dc,
            n_ac: d.n_ac,
            battery_capacity_kwh: d.battery_capacity_kwh,
            battery_p_max_kw: d.battery_p_max_kw,
        }
    }
}

impl StationLayout {
    /// Concrete station config for this layout (+ the entry's V2G flag).
    pub fn station_config(&self, v2g: bool) -> StationConfig {
        StationConfig {
            n_dc: self.n_dc,
            n_ac: self.n_ac,
            battery_capacity_kwh: self.battery_capacity_kwh,
            battery_p_max_kw: self.battery_p_max_kw,
            v2g,
            ..StationConfig::default()
        }
    }

    fn from_json(j: &Json) -> Result<StationLayout> {
        reject_unknown_keys(
            j,
            &["n_dc", "n_ac", "battery_capacity_kwh", "battery_p_max_kw"],
            "layout",
        )?;
        let d = StationLayout::default();
        let num = |key: &str, dflt: f32| -> Result<f32> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => v
                    .as_f64()
                    .map(|x| x as f32)
                    .ok_or_else(|| anyhow!("layout field \"{key}\" must be a number")),
            }
        };
        let count = |key: &str, dflt: usize| -> Result<usize> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| anyhow!("layout field \"{key}\" must be a count")),
            }
        };
        Ok(StationLayout {
            n_dc: count("n_dc", d.n_dc)?,
            n_ac: count("n_ac", d.n_ac)?,
            battery_capacity_kwh: num("battery_capacity_kwh", d.battery_capacity_kwh)?,
            battery_p_max_kw: num("battery_p_max_kw", d.battery_p_max_kw)?,
        })
    }
}

/// One grid entry: `lanes` env lanes spread over the cross product
/// country × year × traffic × profile, on one station layout, optionally
/// V2G-enabled.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub lanes: usize,
    pub countries: Vec<String>,
    pub years: Vec<u32>,
    pub traffics: Vec<String>,
    /// Arrival/user-profile scenario names (the paper's bundled
    /// scenarios: shopping | work | residential | highway).
    pub profiles: Vec<String>,
    /// Car-catalog region used when artifacts are available.
    pub region: String,
    pub layout: StationLayout,
    pub v2g: bool,
    /// Feeder coupling (`grid` key): entries sharing a feeder name form
    /// one coupling group whose summed draw is capped at `capacity_kw`.
    /// `capacity_kw: null` (or no `grid` key) keeps the entry uncoupled —
    /// byte-for-byte today's semantics.
    pub grid: Option<GridSpec>,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            name: "spec".into(),
            lanes: 4,
            countries: vec!["NL".into()],
            years: vec![2021],
            traffics: vec!["medium".into()],
            profiles: vec!["shopping".into()],
            region: "EU".into(),
            layout: StationLayout::default(),
            v2g: false,
            grid: None,
        }
    }
}

/// Parse one entry's `grid` object:
/// `{"feeder": "name", "capacity_kw": N | null, "policy":
/// "proportional" | "price-feedback"}`. `capacity_kw` absent or null
/// documents the feeder without coupling; `policy` defaults to
/// proportional.
fn grid_from_json(j: &Json) -> Result<GridSpec> {
    reject_unknown_keys(j, &["feeder", "capacity_kw", "policy"], "grid")?;
    let feeder = j
        .get("feeder")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("grid needs a \"feeder\" name"))?
        .to_string();
    let capacity_kw = match j.get("capacity_kw") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_f64()
                .map(|x| x as f32)
                .ok_or_else(|| anyhow!("grid \"capacity_kw\" must be a number or null"))?,
        ),
    };
    let policy = match j.get("policy") {
        None => CurtailPolicy::Proportional,
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("grid \"policy\" must be a string"))?;
            CurtailPolicy::parse(s).ok_or_else(|| {
                anyhow!("grid \"policy\" must be \"proportional\" or \"price-feedback\" (got \"{s}\")")
            })?
        }
    };
    Ok(GridSpec { feeder, capacity_kw, policy })
}

impl ScenarioSpec {
    /// Cross product of the grid axes as fully-specified scenarios.
    pub fn cells(&self) -> Vec<Scenario> {
        let mut out = Vec::new();
        for profile in &self.profiles {
            for country in &self.countries {
                for &year in &self.years {
                    for traffic in &self.traffics {
                        out.push(Scenario {
                            scenario: profile.clone(),
                            region: self.region.clone(),
                            country: country.clone(),
                            year,
                            traffic: traffic.clone(),
                            ..Scenario::default()
                        });
                    }
                }
            }
        }
        out
    }

    /// Named-error validation of the grid axes. Empty axes collapse the
    /// cross product to nothing, and a repeated axis value makes two grid
    /// cells resolve to the SAME scenario — the [`TableCache`] would then
    /// silently dedup them and the entry would train on fewer distinct
    /// cells than its spec claims. Both are almost certainly config typos,
    /// so they are rejected here (called from the JSON loader and from
    /// [`expand`], covering programmatically-built specs too).
    pub fn validate(&self) -> Result<()> {
        for (axis, n) in [
            ("countries", self.countries.len()),
            ("years", self.years.len()),
            ("traffics", self.traffics.len()),
            ("profiles", self.profiles.len()),
        ] {
            if n == 0 {
                bail!(
                    "fleet entry '{}': axis \"{axis}\" is empty (grid would have no cells)",
                    self.name
                );
            }
        }
        let mut seen = BTreeSet::new();
        for sc in self.cells() {
            let name = cell_name(&sc);
            if !seen.insert(name.clone()) {
                bail!(
                    "fleet entry '{}': duplicate scenario cell '{name}' \
                     (an axis value is repeated)",
                    self.name
                );
            }
        }
        if let Some(g) = &self.grid {
            if g.feeder.is_empty() {
                bail!("fleet entry '{}': grid \"feeder\" must be non-empty", self.name);
            }
            if let Some(cap) = g.capacity_kw {
                if !cap.is_finite() || cap <= 0.0 {
                    bail!(
                        "fleet entry '{}': grid \"capacity_kw\" must be finite and > 0 \
                         (got {cap}); use null for an uncoupled feeder",
                        self.name
                    );
                }
            }
        }
        Ok(())
    }

    fn from_json(j: &Json) -> Result<ScenarioSpec> {
        reject_unknown_keys(
            j,
            &[
                "name", "lanes", "countries", "years", "traffics", "profiles", "region",
                "layout", "v2g", "grid",
            ],
            "fleet entry",
        )?;
        let d = ScenarioSpec::default();
        let str_list = |key: &str, dflt: Vec<String>| -> Result<Vec<String>> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => v
                    .as_str_vec()
                    .ok_or_else(|| anyhow!("\"{key}\" must be an array of strings")),
            }
        };
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("fleet entry needs a \"name\""))?;
        let lanes = j
            .get("lanes")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("fleet entry '{name}' needs a \"lanes\" count"))?;
        let years = match j.get("years") {
            None => d.years,
            Some(v) => v
                .as_arr()
                .ok_or_else(|| anyhow!("\"years\" must be an array"))?
                .iter()
                .map(|y| {
                    y.as_f64()
                        .map(|x| x as u32)
                        .ok_or_else(|| anyhow!("\"years\" entries must be numbers"))
                })
                .collect::<Result<_>>()?,
        };
        let layout = match j.get("layout") {
            None => d.layout,
            Some(l) => StationLayout::from_json(l)
                .with_context(|| format!("fleet entry '{name}' layout"))?,
        };
        let spec = ScenarioSpec {
            lanes,
            countries: str_list("countries", d.countries)?,
            years,
            traffics: str_list("traffics", d.traffics)?,
            profiles: str_list("profiles", d.profiles)?,
            region: j
                .get("region")
                .and_then(Json::as_str)
                .unwrap_or(&d.region)
                .to_string(),
            layout,
            v2g: j.get("v2g").and_then(Json::as_bool).unwrap_or(false),
            grid: match j.get("grid") {
                None => None,
                Some(g) => Some(
                    grid_from_json(g).with_context(|| format!("fleet entry '{name}' grid"))?,
                ),
            },
            name,
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// A whole fleet: several grid entries plus the expansion seed and an
/// optional list of scenario cells (named as `profile/country/year/traffic`,
/// see [`cell_name`]) carved out of training for zero-shot eval.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub seed: u64,
    pub specs: Vec<ScenarioSpec>,
    /// Cell names excluded from every training lane. [`expand`] still
    /// builds their tables (into [`FamilyPlan::holdout_tables`]) so eval
    /// can report zero-shot per-cell numbers on them.
    pub holdout: Vec<String>,
}

impl FleetSpec {
    /// Built-in demo fleet: three structurally different station families
    /// — the paper's mixed AC/DC station over a 4-cell scenario grid, a
    /// DC-fast V2G plaza, and an AC-only battery-less lot. `lanes_scale`
    /// multiplies every entry's lane count (bench sweeps drive it).
    pub fn demo(seed: u64, lanes_scale: usize) -> FleetSpec {
        let k = lanes_scale.max(1);
        FleetSpec {
            seed,
            specs: vec![
                ScenarioSpec {
                    name: "mixed-ac-dc".into(),
                    lanes: 8 * k,
                    years: vec![2021, 2022],
                    traffics: vec!["medium".into(), "high".into()],
                    ..ScenarioSpec::default()
                },
                ScenarioSpec {
                    name: "dc-plaza-v2g".into(),
                    lanes: 8 * k,
                    profiles: vec!["work".into()],
                    layout: StationLayout { n_dc: 8, n_ac: 0, ..StationLayout::default() },
                    v2g: true,
                    ..ScenarioSpec::default()
                },
                ScenarioSpec {
                    name: "ac-lot".into(),
                    lanes: 4 * k,
                    traffics: vec!["low".into()],
                    layout: StationLayout {
                        n_dc: 0,
                        n_ac: 8,
                        battery_capacity_kwh: 0.0,
                        battery_p_max_kw: 0.0,
                    },
                    ..ScenarioSpec::default()
                },
            ],
            holdout: Vec::new(),
        }
    }

    /// Demo fleet resized to roughly `total_lanes` lanes split 2:2:1
    /// across the three families (bench sweeps drive arbitrary batch
    /// sizes that the `lanes_scale` multiplier of [`FleetSpec::demo`]
    /// cannot hit).
    pub fn demo_total(seed: u64, total_lanes: usize) -> FleetSpec {
        let mut f = FleetSpec::demo(seed, 1);
        let t = total_lanes.max(5);
        let l0 = 2 * t / 5;
        let l1 = 2 * t / 5;
        f.specs[0].lanes = l0;
        f.specs[1].lanes = l1;
        f.specs[2].lanes = t - l0 - l1;
        f
    }

    /// [`FleetSpec::demo`] with all three families coupled on one shared
    /// feeder ("metro-west"), proportionally curtailed. Capacity scales
    /// with the lane count (50 kW/lane — well under the 600 kW a station
    /// root can draw) so the feeder genuinely binds under aggressive
    /// charging at any fleet size.
    pub fn demo_coupled(seed: u64, lanes_scale: usize) -> FleetSpec {
        let mut f = FleetSpec::demo(seed, lanes_scale);
        Self::couple_demo(&mut f);
        f
    }

    /// [`FleetSpec::demo_total`] with the same shared-feeder coupling as
    /// [`FleetSpec::demo_coupled`] (bench sweeps drive arbitrary totals).
    pub fn demo_coupled_total(seed: u64, total_lanes: usize) -> FleetSpec {
        let mut f = FleetSpec::demo_total(seed, total_lanes);
        Self::couple_demo(&mut f);
        f
    }

    fn couple_demo(f: &mut FleetSpec) {
        let total: usize = f.specs.iter().map(|s| s.lanes).sum();
        let grid = GridSpec {
            feeder: "metro-west".into(),
            capacity_kw: Some(50.0 * total as f32),
            policy: CurtailPolicy::Proportional,
        };
        for s in &mut f.specs {
            s.grid = Some(grid.clone());
        }
    }

    pub fn from_json_file(path: &str) -> Result<FleetSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fleet spec {path}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing fleet spec {path}"))?;
        FleetSpec::from_json(&j).with_context(|| format!("fleet spec {path}"))
    }

    /// Schema (README §Scenario fleets & V2G):
    /// `{"seed": N, "fleet": [{"name", "lanes", "countries", "years",
    /// "traffics", "profiles", "region", "layout": {...}, "v2g"}, ...],
    /// "holdout": ["profile/country/year/traffic", ...]}`.
    pub fn from_json(j: &Json) -> Result<FleetSpec> {
        reject_unknown_keys(j, &["seed", "fleet", "holdout"], "fleet spec")?;
        let seed = j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let entries = j
            .get("fleet")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("fleet spec needs a top-level \"fleet\" array"))?;
        let mut specs = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            specs.push(ScenarioSpec::from_json(e).with_context(|| format!("fleet entry {i}"))?);
        }
        let holdout = match j.get("holdout") {
            None => Vec::new(),
            Some(v) => v.as_str_vec().ok_or_else(|| {
                anyhow!("\"holdout\" must be an array of cell names (profile/country/year/traffic)")
            })?,
        };
        Ok(FleetSpec { seed, specs, holdout })
    }
}

/// Dedup cache: scenarios whose resolved tables would be identical share
/// one `Arc<ScenarioTables>` — built once, never cloned per lane.
#[derive(Default)]
pub struct TableCache {
    map: BTreeMap<String, Arc<ScenarioTables>>,
}

impl TableCache {
    pub fn new() -> TableCache {
        TableCache::default()
    }

    /// Cache key: every `Scenario` field that influences table contents
    /// (float fields keyed by bit pattern, so -0.0 vs 0.0 is the only
    /// equal-but-distinct case — harmless for a cache).
    fn key(sc: &Scenario) -> String {
        format!(
            "{}|{}|{}|{}|{}|{:?}|{}|{}|{}",
            sc.scenario,
            sc.region,
            sc.country,
            sc.year,
            sc.traffic,
            sc.alpha.map(f32::to_bits),
            sc.beta.to_bits(),
            sc.p_sell.to_bits(),
            sc.feed_in_ratio.to_bits(),
        )
    }

    pub fn get(&mut self, store: Option<&DataStore>, sc: &Scenario) -> Result<Arc<ScenarioTables>> {
        let key = Self::key(sc);
        if let Some(t) = self.map.get(&key) {
            return Ok(Arc::clone(t));
        }
        let tables = match store {
            Some(s) => {
                check_scenario_known(s, sc)?;
                ScenarioTables::build(s, sc).with_context(|| {
                    format!(
                        "building tables for scenario {} {} {}/{} traffic={}",
                        sc.scenario, sc.region, sc.country, sc.year, sc.traffic
                    )
                })?
            }
            None => ScenarioTables::synthetic_for(sc),
        };
        let arc = Arc::new(tables);
        self.map.insert(key, Arc::clone(&arc));
        Ok(arc)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Pre-flight the data-store lookups `ScenarioTables::build` performs
/// with panicking `BTreeMap` indexing, so a typo'd fleet entry fails
/// with the bad key (and the known ones) named instead of an opaque
/// `key not found` panic. (Without artifacts, synthetic tables accept
/// any names.)
fn check_scenario_known(store: &DataStore, sc: &Scenario) -> Result<()> {
    if !store.arrival_shapes.contains_key(&sc.scenario)
        || !store.user_profiles.contains_key(&sc.scenario)
    {
        bail!(
            "unknown profile/scenario '{}' (have {:?})",
            sc.scenario,
            store.arrival_shapes.keys().collect::<Vec<_>>()
        );
    }
    if !store.car_weights.contains_key(&sc.region) {
        bail!(
            "unknown region '{}' (have {:?})",
            sc.region,
            store.car_weights.keys().collect::<Vec<_>>()
        );
    }
    if !store.traffic.contains_key(&sc.traffic) {
        bail!(
            "unknown traffic level '{}' (have {:?})",
            sc.traffic,
            store.traffic.keys().collect::<Vec<_>>()
        );
    }
    store.price(&sc.country, sc.year).map(|_| ())
}

/// Human-readable name of one scenario cell (profile/country/year/traffic)
/// — what per-cell eval reporting prints next to each number.
pub fn cell_name(sc: &Scenario) -> String {
    format!("{}/{}/{}/{}", sc.scenario, sc.country, sc.year, sc.traffic)
}

/// One station family: every lane whose `StationConfig` (hence obs and
/// action space) is identical, ready to back one `VectorEnv`.
/// `cell_names[i]` names the scenario cell behind `tables[i]`.
pub struct FamilyPlan {
    pub label: String,
    pub cfg: StationConfig,
    /// The family's coupling spec, normalized: `Some` only for a feeder
    /// with a concrete capacity (a `capacity_kw: null` grid key is
    /// documentation, not coupling, and normalizes to `None` so the entry
    /// merges and behaves exactly like an ungridded one). Families on the
    /// same feeder form one coupling group in the fleet rollout.
    pub grid: Option<GridSpec>,
    pub tables: Vec<Arc<ScenarioTables>>,
    pub cell_names: Vec<String>,
    pub lane_scenario: Vec<usize>,
    pub seeds: Vec<u64>,
    /// Held-out scenario cells of this family (`holdout` key): tables are
    /// built so eval can run zero-shot on them, but NO training lane is
    /// ever assigned one. `holdout_names[i]` names `holdout_tables[i]`.
    pub holdout_tables: Vec<Arc<ScenarioTables>>,
    pub holdout_names: Vec<String>,
}

/// Shape of the whole scenario grid as one policy input/output spec: the
/// padded observation width (grid-wide max) and one head spec per family
/// in deterministic [`expand`] order. This is what the shared-trunk
/// generalist ([`crate::baselines::generalist::GeneralistLearner`]) is
/// built from: trunk input is `pad_obs + heads.len()` (obs padded with
/// zeros plus a family one-hot block), and family `f` decodes through
/// `heads[f].action_nvec`.
#[derive(Debug, Clone, PartialEq)]
pub struct GridShape {
    pub pad_obs: usize,
    pub heads: Vec<HeadSpec>,
}

/// Per-family slice of the [`GridShape`]: the family's native obs width
/// and factored action dims.
#[derive(Debug, Clone, PartialEq)]
pub struct HeadSpec {
    pub label: String,
    pub obs_dim: usize,
    pub action_nvec: Vec<usize>,
}

impl GridShape {
    /// Derive the grid shape from expanded family plans. Family index ==
    /// position in `plans` — the same deterministic order the fused
    /// rollout and the cross-family update iterate in.
    pub fn from_plans(plans: &[FamilyPlan]) -> GridShape {
        let heads: Vec<HeadSpec> = plans
            .iter()
            .map(|f| HeadSpec {
                label: f.label.clone(),
                obs_dim: core::obs_dim(&f.cfg),
                action_nvec: core::action_nvec(&f.cfg),
            })
            .collect();
        let pad_obs = heads.iter().map(|h| h.obs_dim).max().unwrap_or(0);
        GridShape { pad_obs, heads }
    }

    /// Trunk input width: padded obs + one-hot family id.
    pub fn in_dim(&self) -> usize {
        self.pad_obs + self.heads.len()
    }

    /// `(obs_dim, action_nvec)` pairs in family order — the constructor
    /// argument of `GeneralistLearner::new`.
    pub fn learner_specs(&self) -> Vec<(usize, Vec<usize>)> {
        self.heads.iter().map(|h| (h.obs_dim, h.action_nvec.clone())).collect()
    }
}

/// Expand a [`FleetSpec`] into per-family lane plans.
///
/// Deterministic and seeded: the cell order inside each entry is shuffled
/// with a `CounterRng` derived from `(fleet.seed, entry index)` and lanes
/// round-robin over the shuffled order (every cell is visited before any
/// repeats); per-lane RNG seeds come from one derived seeder stream, so
/// they are stable regardless of how entries regroup into families.
pub fn expand(fleet: &FleetSpec, store: Option<&DataStore>) -> Result<Vec<FamilyPlan>> {
    if fleet.specs.is_empty() {
        bail!("fleet spec has no scenario entries");
    }
    for (i, h) in fleet.holdout.iter().enumerate() {
        if fleet.holdout[..i].contains(h) {
            bail!("duplicate holdout cell '{h}' in fleet spec");
        }
    }
    let mut holdout_used = vec![false; fleet.holdout.len()];
    // A feeder name is a physical asset: two entries naming the same
    // feeder with different capacities/policies describe contradictory
    // hardware, which would otherwise expand into two coupling groups
    // that silently double-count the feeder.
    let mut feeders: BTreeMap<&str, (&GridSpec, &str)> = BTreeMap::new();
    for spec in &fleet.specs {
        let Some(g) = &spec.grid else { continue };
        match feeders.get(g.feeder.as_str()) {
            None => {
                feeders.insert(&g.feeder, (g, &spec.name));
            }
            Some((prev, prev_entry)) if *prev != g => {
                bail!(
                    "fleet entries '{}' and '{}' both name feeder \"{}\" but with \
                     different capacity_kw/policy — one feeder, one definition",
                    prev_entry,
                    spec.name,
                    g.feeder
                );
            }
            Some(_) => {}
        }
    }
    let mut cache = TableCache::new();
    let mut families: Vec<FamilyPlan> = Vec::new();
    let mut seeder = CounterRng::derive(fleet.seed, 0xF1EE7);
    for (s_idx, spec) in fleet.specs.iter().enumerate() {
        if spec.lanes == 0 {
            bail!("fleet entry '{}' has zero lanes", spec.name);
        }
        spec.validate()?;
        // Carve held-out cells from this entry's grid BEFORE the order
        // shuffle: training lanes round-robin over the surviving cells
        // only, so a holdout cell can never reach a lane. With no holdout
        // the partition is the identity and every seeded draw below is
        // byte-for-byte what it was without the feature.
        let mut cells = Vec::new();
        let mut held = Vec::new();
        for sc in spec.cells() {
            let name = cell_name(&sc);
            match fleet.holdout.iter().position(|h| h == &name) {
                Some(k) => {
                    holdout_used[k] = true;
                    held.push(sc);
                }
                None => cells.push(sc),
            }
        }
        if cells.is_empty() {
            if held.is_empty() {
                bail!(
                    "fleet entry '{}' expands to an empty grid \
                     (check countries/years/traffics/profiles)",
                    spec.name
                );
            }
            bail!(
                "fleet entry '{}' has every scenario cell held out — nothing left to train on",
                spec.name
            );
        }
        // Normalize: a grid key without a concrete capacity is pure
        // documentation — the entry stays uncoupled and must merge (and
        // behave) exactly like one with no grid key at all.
        let grid = spec.grid.clone().filter(GridSpec::coupled);
        let mut cfg = spec.layout.station_config(spec.v2g);
        cfg.grid_coupled = grid.is_some();
        cfg.validate()
            .with_context(|| format!("fleet entry '{}' layout", spec.name))?;
        let mut order: Vec<usize> = (0..cells.len()).collect();
        let mut rng = CounterRng::derive(fleet.seed, s_idx as u64 + 1);
        for i in (1..order.len()).rev() {
            let j = rng.below(i as u32 + 1) as usize;
            order.swap(i, j);
        }
        // Families merge on config AND coupling spec: same feeder, same
        // electrical shape. Coupled-vs-uncoupled already differ in
        // `cfg.grid_coupled`; the grid term keeps two coupled entries on
        // DIFFERENT feeders in separate families so each backs its own
        // coupling group.
        let fam_idx = match families.iter().position(|f| f.cfg == cfg && f.grid == grid) {
            Some(i) => {
                families[i].label.push('+');
                families[i].label.push_str(&spec.name);
                i
            }
            None => {
                families.push(FamilyPlan {
                    label: spec.name.clone(),
                    cfg: cfg.clone(),
                    grid: grid.clone(),
                    tables: Vec::new(),
                    cell_names: Vec::new(),
                    lane_scenario: Vec::new(),
                    seeds: Vec::new(),
                    holdout_tables: Vec::new(),
                    holdout_names: Vec::new(),
                });
                families.len() - 1
            }
        };
        let fam = &mut families[fam_idx];
        for lane in 0..spec.lanes {
            let sc = &cells[order[lane % cells.len()]];
            let table = cache
                .get(store, sc)
                .with_context(|| format!("fleet entry '{}'", spec.name))?;
            let t_idx = match fam.tables.iter().position(|t| Arc::ptr_eq(t, &table)) {
                Some(i) => i,
                None => {
                    fam.tables.push(Arc::clone(&table));
                    fam.cell_names.push(cell_name(sc));
                    fam.tables.len() - 1
                }
            };
            fam.lane_scenario.push(t_idx);
            fam.seeds.push(seeder.next_u64());
        }
        for sc in &held {
            let name = cell_name(sc);
            if fam.holdout_names.contains(&name) {
                continue;
            }
            let table = cache
                .get(store, sc)
                .with_context(|| format!("fleet entry '{}' holdout", spec.name))?;
            fam.holdout_tables.push(table);
            fam.holdout_names.push(name);
        }
    }
    for (h, used) in fleet.holdout.iter().zip(&holdout_used) {
        if !used {
            bail!(
                "holdout cell '{h}' matches no scenario cell in any fleet entry \
                 (cells are named profile/country/year/traffic)"
            );
        }
    }
    Ok(families)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_expands_to_three_heterogeneous_families() {
        let spec = FleetSpec::demo(7, 1);
        let fams = expand(&spec, None).unwrap();
        assert_eq!(fams.len(), 3);
        // Structurally different: distinct obs/action spaces.
        let dims: Vec<usize> = fams
            .iter()
            .map(|f| crate::env::core::obs_dim(&f.cfg))
            .collect();
        assert_ne!(dims[0], dims[1]);
        assert!(fams.iter().any(|f| f.cfg.v2g), "demo must include a V2G family");
        assert!(
            fams.iter().any(|f| f.cfg.battery_capacity_kwh == 0.0),
            "demo must include a battery-less family"
        );
        for f in &fams {
            assert_eq!(f.lane_scenario.len(), f.seeds.len());
            assert!(!f.tables.is_empty());
            assert!(f.lane_scenario.iter().all(|&i| i < f.tables.len()));
            // One name per distinct cell, all distinct within a family.
            assert_eq!(f.tables.len(), f.cell_names.len());
            for (i, a) in f.cell_names.iter().enumerate() {
                assert!(!a.is_empty());
                for b in &f.cell_names[i + 1..] {
                    assert_ne!(a, b, "duplicate cell name in family {}", f.label);
                }
            }
        }
    }

    #[test]
    fn expansion_is_deterministic_and_seed_sensitive() {
        let a = expand(&FleetSpec::demo(7, 1), None).unwrap();
        let b = expand(&FleetSpec::demo(7, 1), None).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.lane_scenario, y.lane_scenario);
            assert_eq!(x.seeds, y.seeds);
        }
        let c = expand(&FleetSpec::demo(8, 1), None).unwrap();
        assert_ne!(a[0].seeds, c[0].seeds, "different fleet seed, same lane seeds");
    }

    #[test]
    fn cache_dedups_repeated_scenarios() {
        // 8 lanes over a 4-cell grid: exactly 4 tables built, lanes
        // sharing a cell share the same Arc.
        let spec = FleetSpec {
            seed: 3,
            specs: vec![ScenarioSpec {
                lanes: 8,
                years: vec![2021, 2022],
                traffics: vec!["medium".into(), "high".into()],
                ..ScenarioSpec::default()
            }],
            holdout: Vec::new(),
        };
        let fams = expand(&spec, None).unwrap();
        assert_eq!(fams.len(), 1);
        let f = &fams[0];
        assert_eq!(f.tables.len(), 4, "one shared table per distinct cell");
        assert_eq!(f.lane_scenario.len(), 8);
        // Round-robin over the shuffled order: each cell used twice.
        let mut counts = vec![0usize; f.tables.len()];
        for &i in &f.lane_scenario {
            counts[i] += 1;
        }
        assert!(counts.iter().all(|&c| c == 2), "cells unevenly covered: {counts:?}");
    }

    #[test]
    fn same_layout_entries_merge_into_one_family() {
        let mut a = ScenarioSpec { name: "a".into(), lanes: 3, ..ScenarioSpec::default() };
        a.traffics = vec!["low".into()];
        let b = ScenarioSpec { name: "b".into(), lanes: 2, ..ScenarioSpec::default() };
        let fams =
            expand(&FleetSpec { seed: 1, specs: vec![a, b], holdout: Vec::new() }, None).unwrap();
        assert_eq!(fams.len(), 1);
        assert_eq!(fams[0].lane_scenario.len(), 5);
        assert_eq!(fams[0].label, "a+b");
    }

    #[test]
    fn json_round_trip_parses_schema() {
        let text = r#"{
            "seed": 11,
            "fleet": [
                {"name": "nl", "lanes": 6, "countries": ["NL"],
                 "years": [2021, 2023], "traffics": ["low", "high"],
                 "profiles": ["shopping"],
                 "layout": {"n_dc": 4, "n_ac": 2}, "v2g": true}
            ]
        }"#;
        let spec = FleetSpec::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(spec.seed, 11);
        assert_eq!(spec.specs.len(), 1);
        let s = &spec.specs[0];
        assert_eq!(s.lanes, 6);
        assert_eq!(s.years, vec![2021, 2023]);
        assert_eq!(s.layout.n_dc, 4);
        assert!(s.v2g);
        assert_eq!(s.cells().len(), 4);
        // missing required fields error with the entry named
        let bad = r#"{"fleet": [{"name": "x"}]}"#;
        let err = FleetSpec::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("lanes"), "{err:#}");
    }

    #[test]
    fn duplicate_cells_and_empty_axes_are_rejected_not_deduped() {
        // A repeated axis value used to slip through: TableCache collapsed
        // the duplicate cells and training silently covered fewer cells
        // than the spec claimed.
        let dup = r#"{"fleet": [{"name": "d", "lanes": 4,
                                 "years": [2021, 2021]}]}"#;
        let err = FleetSpec::from_json(&Json::parse(dup).unwrap()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("duplicate scenario cell"), "{msg}");
        assert!(msg.contains("'d'"), "entry not named: {msg}");

        let empty = r#"{"fleet": [{"name": "e", "lanes": 4, "traffics": []}]}"#;
        let err = FleetSpec::from_json(&Json::parse(empty).unwrap()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("\"traffics\" is empty"), "{msg}");

        // expand() validates too, for programmatically-built specs.
        let mut spec = ScenarioSpec { lanes: 2, ..ScenarioSpec::default() };
        spec.countries = vec!["NL".into(), "NL".into()];
        let err = expand(
            &FleetSpec { seed: 1, specs: vec![spec], holdout: Vec::new() },
            None,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("duplicate scenario cell"), "{err:#}");
    }

    #[test]
    fn holdout_cells_are_carved_out_of_training() {
        let mut fleet = FleetSpec::demo(7, 1);
        // demo entry 0 grid: shopping × NL × {2021,2022} × {medium,high}.
        let held = "shopping/NL/2022/high".to_string();
        fleet.holdout = vec![held.clone()];
        let fams = expand(&fleet, None).unwrap();
        let with_holdout: Vec<_> =
            fams.iter().filter(|f| !f.holdout_names.is_empty()).collect();
        assert_eq!(with_holdout.len(), 1, "exactly one family holds the cell");
        let f = with_holdout[0];
        assert_eq!(f.holdout_names, vec![held.clone()]);
        assert_eq!(f.holdout_tables.len(), 1);
        // The held-out cell appears in NO training assignment.
        assert!(
            !f.cell_names.contains(&held),
            "holdout cell leaked into training cells: {:?}",
            f.cell_names
        );
        assert_eq!(f.cell_names.len(), 3, "3 of 4 grid cells remain trainable");
        // Same lane count as without holdout — lanes redistribute over the
        // surviving cells rather than disappearing.
        let base = expand(&FleetSpec::demo(7, 1), None).unwrap();
        let base_lanes: usize = base.iter().map(|f| f.lane_scenario.len()).sum();
        let lanes: usize = fams.iter().map(|f| f.lane_scenario.len()).sum();
        assert_eq!(lanes, base_lanes);
    }

    #[test]
    fn holdout_validation_names_bad_cells() {
        let mut fleet = FleetSpec::demo(7, 1);
        fleet.holdout = vec!["nope/XX/1999/low".into()];
        let err = expand(&fleet, None).unwrap_err();
        assert!(
            format!("{err:#}").contains("nope/XX/1999/low"),
            "unknown holdout not named: {err:#}"
        );

        let mut fleet = FleetSpec::demo(7, 1);
        fleet.holdout =
            vec!["shopping/NL/2022/high".into(), "shopping/NL/2022/high".into()];
        let err = expand(&fleet, None).unwrap_err();
        assert!(format!("{err:#}").contains("duplicate holdout"), "{err:#}");

        // Holding out EVERY cell of an entry is an error, not a 0-lane plan.
        let mut fleet = FleetSpec::demo(7, 1);
        fleet.holdout = vec!["work/NL/2021/medium".into()]; // dc-plaza-v2g's only cell
        let err = expand(&fleet, None).unwrap_err();
        assert!(format!("{err:#}").contains("every scenario cell held out"), "{err:#}");
    }

    #[test]
    fn holdout_key_parses_and_empty_holdout_changes_nothing() {
        let text = r#"{
            "seed": 5,
            "fleet": [{"name": "nl", "lanes": 4, "years": [2021, 2022]}],
            "holdout": ["shopping/NL/2022/medium"]
        }"#;
        let spec = FleetSpec::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(spec.holdout, vec!["shopping/NL/2022/medium".to_string()]);
        let fams = expand(&spec, None).unwrap();
        assert_eq!(fams[0].holdout_names.len(), 1);

        // No holdout key → expansion identical to the pre-holdout planner
        // (the carve-out partition is the identity).
        let a = expand(&FleetSpec::demo(7, 1), None).unwrap();
        let mut with_empty = FleetSpec::demo(7, 1);
        with_empty.holdout = Vec::new();
        let b = expand(&with_empty, None).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.lane_scenario, y.lane_scenario);
            assert_eq!(x.seeds, y.seeds);
            assert_eq!(x.cell_names, y.cell_names);
        }
    }

    #[test]
    fn grid_shape_matches_family_plans() {
        let fams = expand(&FleetSpec::demo(7, 1), None).unwrap();
        let shape = GridShape::from_plans(&fams);
        assert_eq!(shape.heads.len(), 3);
        let dims: Vec<usize> =
            fams.iter().map(|f| crate::env::core::obs_dim(&f.cfg)).collect();
        assert_eq!(shape.pad_obs, *dims.iter().max().unwrap());
        assert_eq!(shape.in_dim(), shape.pad_obs + 3);
        for (h, f) in shape.heads.iter().zip(&fams) {
            assert_eq!(h.label, f.label);
            assert_eq!(h.obs_dim, crate::env::core::obs_dim(&f.cfg));
            assert_eq!(h.action_nvec, crate::env::core::action_nvec(&f.cfg));
            assert!(h.obs_dim <= shape.pad_obs);
        }
        let specs = shape.learner_specs();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].0, shape.heads[0].obs_dim);
    }

    #[test]
    fn unknown_keys_are_rejected_with_name() {
        // Top-level fleet spec.
        let bad = r#"{"seed": 1, "flet": [], "fleet": [{"name": "a", "lanes": 1}]}"#;
        let err = FleetSpec::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown key \"flet\""), "{msg}");
        assert!(msg.contains("fleet spec"), "{msg}");
        // Entry level: a typo'd axis used to be silently ignored.
        let bad = r#"{"fleet": [{"name": "a", "lanes": 1, "trafics": ["low"]}]}"#;
        let err = FleetSpec::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown key \"trafics\""), "{msg}");
        // Layout level.
        let bad = r#"{"fleet": [{"name": "a", "lanes": 1,
                                 "layout": {"n_dcs": 4}}]}"#;
        let err = FleetSpec::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown key \"n_dcs\""), "{msg}");
        assert!(msg.contains("'a'"), "entry not named: {msg}");
        // Grid level.
        let bad = r#"{"fleet": [{"name": "a", "lanes": 1,
                                 "grid": {"feeder": "f", "capacity": 100}}]}"#;
        let err = FleetSpec::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown key \"capacity\""), "{msg}");
        assert!(msg.contains("capacity_kw"), "allowed keys not listed: {msg}");
    }

    #[test]
    fn grid_key_parses_and_null_capacity_is_uncoupled() {
        let text = r#"{"fleet": [
            {"name": "a", "lanes": 2,
             "grid": {"feeder": "west", "capacity_kw": 300,
                      "policy": "price-feedback"}},
            {"name": "b", "lanes": 2, "traffics": ["low"],
             "grid": {"feeder": "east", "capacity_kw": null}}
        ]}"#;
        let spec = FleetSpec::from_json(&Json::parse(text).unwrap()).unwrap();
        let a = spec.specs[0].grid.as_ref().unwrap();
        assert_eq!(a.feeder, "west");
        assert_eq!(a.capacity_kw, Some(300.0));
        assert_eq!(a.policy, CurtailPolicy::PriceFeedback);
        assert!(a.coupled());
        let b = spec.specs[1].grid.as_ref().unwrap();
        assert_eq!(b.capacity_kw, None);
        assert!(!b.coupled(), "null capacity documents the feeder without coupling");
        // Policy defaults to proportional; bad names error with the value.
        let dflt = r#"{"fleet": [{"name": "a", "lanes": 1, "grid": {"feeder": "f"}}]}"#;
        let spec = FleetSpec::from_json(&Json::parse(dflt).unwrap()).unwrap();
        assert_eq!(spec.specs[0].grid.as_ref().unwrap().policy, CurtailPolicy::Proportional);
        let bad = r#"{"fleet": [{"name": "a", "lanes": 1,
                                 "grid": {"feeder": "f", "policy": "hard"}}]}"#;
        let err = FleetSpec::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("\"hard\""), "{err:#}");
        // Validation: capacity must be finite and positive when set.
        let bad = r#"{"fleet": [{"name": "a", "lanes": 1,
                                 "grid": {"feeder": "f", "capacity_kw": -5}}]}"#;
        let err = FleetSpec::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("capacity_kw"), "{err:#}");
        let bad = r#"{"fleet": [{"name": "a", "lanes": 1, "grid": {"feeder": ""}}]}"#;
        let err = FleetSpec::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("feeder"), "{err:#}");
    }

    #[test]
    fn coupled_families_do_not_merge_with_uncoupled_or_other_feeders() {
        let coupled = |name: &str, feeder: &str| ScenarioSpec {
            name: name.into(),
            lanes: 2,
            grid: Some(GridSpec {
                feeder: feeder.into(),
                capacity_kw: Some(200.0),
                policy: CurtailPolicy::Proportional,
            }),
            ..ScenarioSpec::default()
        };
        // Same layout, but coupled vs uncoupled: two families, and only
        // the coupled one grows the headroom obs column.
        let plain = ScenarioSpec { name: "plain".into(), lanes: 2, ..ScenarioSpec::default() };
        let fams = expand(
            &FleetSpec {
                seed: 1,
                specs: vec![coupled("c", "west"), plain],
                holdout: Vec::new(),
            },
            None,
        )
        .unwrap();
        assert_eq!(fams.len(), 2);
        assert!(fams[0].cfg.grid_coupled && fams[0].grid.is_some());
        assert!(!fams[1].cfg.grid_coupled && fams[1].grid.is_none());
        assert_eq!(
            crate::env::core::obs_dim(&fams[0].cfg),
            crate::env::core::obs_dim(&fams[1].cfg) + 1,
            "coupling adds exactly the headroom column"
        );
        // Same layout, different feeders: separate families (separate
        // coupling groups); same feeder merges.
        let fams = expand(
            &FleetSpec {
                seed: 1,
                specs: vec![coupled("c1", "west"), coupled("c2", "east")],
                holdout: Vec::new(),
            },
            None,
        )
        .unwrap();
        assert_eq!(fams.len(), 2, "different feeders must not share a family");
        let mut same = coupled("c2", "west");
        same.traffics = vec!["low".into()];
        let fams = expand(
            &FleetSpec {
                seed: 1,
                specs: vec![coupled("c1", "west"), same],
                holdout: Vec::new(),
            },
            None,
        )
        .unwrap();
        assert_eq!(fams.len(), 1, "same feeder + layout must merge");
        assert_eq!(fams[0].label, "c1+c2");
        assert_eq!(fams[0].grid.as_ref().unwrap().feeder, "west");
    }

    #[test]
    fn null_capacity_grid_expands_byte_identical_to_no_grid() {
        let mut documented = FleetSpec::demo(7, 1);
        for s in &mut documented.specs {
            s.grid = Some(GridSpec {
                feeder: "paper-only".into(),
                capacity_kw: None,
                policy: CurtailPolicy::Proportional,
            });
        }
        let a = expand(&FleetSpec::demo(7, 1), None).unwrap();
        let b = expand(&documented, None).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cfg, y.cfg);
            assert!(!y.cfg.grid_coupled);
            assert_eq!(y.grid, None, "null capacity normalizes to an ungridded family");
            assert_eq!(x.lane_scenario, y.lane_scenario);
            assert_eq!(x.seeds, y.seeds);
            assert_eq!(x.cell_names, y.cell_names);
        }
    }

    #[test]
    fn conflicting_feeder_definitions_are_rejected() {
        let mk = |name: &str, cap: f32| ScenarioSpec {
            name: name.into(),
            lanes: 2,
            grid: Some(GridSpec {
                feeder: "west".into(),
                capacity_kw: Some(cap),
                policy: CurtailPolicy::Proportional,
            }),
            ..ScenarioSpec::default()
        };
        let err = expand(
            &FleetSpec {
                seed: 1,
                specs: vec![mk("a", 200.0), mk("b", 300.0)],
                holdout: Vec::new(),
            },
            None,
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("\"west\""), "feeder not named: {msg}");
        assert!(msg.contains("'a'") && msg.contains("'b'"), "entries not named: {msg}");
    }

    #[test]
    fn demo_coupled_shares_one_feeder_across_all_families() {
        let fams = expand(&FleetSpec::demo_coupled(7, 1), None).unwrap();
        assert_eq!(fams.len(), 3);
        let base = expand(&FleetSpec::demo(7, 1), None).unwrap();
        for (f, b) in fams.iter().zip(&base) {
            assert!(f.cfg.grid_coupled);
            let g = f.grid.as_ref().expect("every demo_coupled family is coupled");
            assert_eq!(g.feeder, "metro-west");
            assert_eq!(g.capacity_kw, Some(50.0 * 20.0));
            // Coupling changes ONLY the obs column — lane assignment and
            // seeds stay exactly the uncoupled demo's.
            assert_eq!(f.lane_scenario, b.lane_scenario);
            assert_eq!(f.seeds, b.seeds);
        }
    }

    #[test]
    fn demo_total_splits_lanes_two_two_one() {
        let f = FleetSpec::demo_total(7, 256);
        let lanes: Vec<usize> = f.specs.iter().map(|s| s.lanes).collect();
        assert_eq!(lanes.iter().sum::<usize>(), 256);
        assert_eq!(lanes, vec![102, 102, 52]);
        let f = FleetSpec::demo_total(7, 1024);
        assert_eq!(f.specs.iter().map(|s| s.lanes).sum::<usize>(), 1024);
    }
}
