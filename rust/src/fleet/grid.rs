//! Feeder coupling: the allocate phase between the fleet's propose and
//! commit dispatches.
//!
//! A coupling group is the set of station families sharing one named
//! feeder (`grid.feeder` in the fleet spec) with a finite `capacity_kw`.
//! Each step, every lane's proposed grid draw (from
//! [`crate::env::core::propose_lane`]) is summed over the group with a
//! **fixed-order pairwise tree reduce** — the same idiom as the PPO
//! update's gradient reduction — over fixed 64-lane blocks in env-then-
//! lane order. The reduction shape is a function of the group's lane
//! count alone, NEVER of `--threads` or the shard plan, which is the
//! whole bitwise-determinism contract: the allocate phase produces the
//! same f32 total however the propose work was sharded.

use crate::baselines::ppo::tree_reduce;
use crate::env::core::GridBudget;

/// Lanes summed sequentially per reduction block. Matches the update
/// path's 64-row chunk granularity; like there, block boundaries are a
/// function of the lane count alone, so the partial sums (and the tree
/// over them) are thread-count-invariant by construction.
pub const REDUCE_BLOCK_LANES: usize = 64;

/// How a coupling group resolves an over-subscribed feeder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CurtailPolicy {
    /// Scale every lane's staged currents by `capacity / total`, so the
    /// committed group draw equals the capacity exactly.
    Proportional,
    /// Deliver the full draw but reprice the import: every lane's buy
    /// price is multiplied by `total / capacity` for the step.
    PriceFeedback,
}

impl CurtailPolicy {
    pub fn parse(s: &str) -> Option<CurtailPolicy> {
        match s {
            "proportional" => Some(CurtailPolicy::Proportional),
            "price-feedback" => Some(CurtailPolicy::PriceFeedback),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            CurtailPolicy::Proportional => "proportional",
            CurtailPolicy::PriceFeedback => "price-feedback",
        }
    }
}

/// One scenario entry's `grid` key. `capacity_kw == None` (the JSON
/// `null` / absent form) documents the feeder without coupling it: the
/// entry keeps today's uncoupled semantics byte for byte.
#[derive(Debug, Clone, PartialEq)]
pub struct GridSpec {
    pub feeder: String,
    pub capacity_kw: Option<f32>,
    pub policy: CurtailPolicy,
}

impl GridSpec {
    /// Whether this spec actually couples its lanes (a concrete capacity).
    pub fn coupled(&self) -> bool {
        self.capacity_kw.is_some()
    }
}

/// Sum proposed per-lane draws (kW) with the fixed-order pairwise tree:
/// sequential sums inside fixed 64-lane blocks, then the same
/// stride-doubling tree the PPO update uses over the block partials. The
/// caller passes the group's lanes concatenated in env order.
pub fn reduce_proposals(grid_kw: &[f32]) -> f32 {
    let mut parts: Vec<f32> = grid_kw
        .chunks(REDUCE_BLOCK_LANES)
        .map(|block| block.iter().sum::<f32>())
        .collect();
    tree_reduce(&mut parts, |a, b| *a += *b);
    parts.first().copied().unwrap_or(0.0)
}

/// Decide the group's per-lane budget from the reduced total. Within
/// capacity (or net injection), the budget is exactly
/// [`GridBudget::UNCURTAILED`], so the commit path stays byte-identical
/// to an uncoupled step.
pub fn allocate(total_kw: f32, capacity_kw: f32, policy: CurtailPolicy) -> GridBudget {
    if total_kw <= capacity_kw || total_kw <= 0.0 {
        return GridBudget::UNCURTAILED;
    }
    match policy {
        CurtailPolicy::Proportional => GridBudget {
            factor: capacity_kw / total_kw,
            buy_mult: 1.0,
        },
        CurtailPolicy::PriceFeedback => GridBudget {
            factor: 1.0,
            buy_mult: total_kw / capacity_kw,
        },
    }
}

/// Normalized feeder headroom for the observation column: 1 when idle,
/// 0 when at/over capacity (net injection also reads as full headroom).
pub fn headroom(total_kw: f32, capacity_kw: f32) -> f32 {
    (1.0 - total_kw.max(0.0) / capacity_kw).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The reduce must be a pure function of the lane count — re-summing
    /// any sharded partition of the same lanes through the same tree
    /// gives the identical f32 (this is what frees the allocate phase
    /// from the shard plan).
    #[test]
    fn reduce_is_fixed_order_and_partition_independent() {
        let lanes: Vec<f32> = (0..517).map(|i| ((i * 37 % 101) as f32).sin() * 50.0).collect();
        let a = reduce_proposals(&lanes);
        let b = reduce_proposals(&lanes);
        assert_eq!(a.to_bits(), b.to_bits());
        // Block partials recombine through the tree, not left-to-right:
        // verify against a hand-rolled block+tree sum.
        let mut parts: Vec<f32> =
            lanes.chunks(REDUCE_BLOCK_LANES).map(|c| c.iter().sum::<f32>()).collect();
        crate::baselines::ppo::tree_reduce(&mut parts, |x, y| *x += *y);
        assert_eq!(a.to_bits(), parts[0].to_bits());
        assert_eq!(reduce_proposals(&[]), 0.0);
    }

    #[test]
    fn allocate_is_uncurtailed_within_capacity() {
        for policy in [CurtailPolicy::Proportional, CurtailPolicy::PriceFeedback] {
            assert_eq!(allocate(300.0, 400.0, policy), GridBudget::UNCURTAILED);
            assert_eq!(allocate(-50.0, 400.0, policy), GridBudget::UNCURTAILED);
            assert_eq!(allocate(400.0, 400.0, policy), GridBudget::UNCURTAILED);
        }
    }

    #[test]
    fn allocate_over_capacity_curtails_or_reprices() {
        let b = allocate(800.0, 400.0, CurtailPolicy::Proportional);
        assert!((b.factor - 0.5).abs() < 1e-6);
        assert_eq!(b.buy_mult, 1.0);
        assert!(b.factor > 0.0 && b.factor < 1.0);
        let b = allocate(800.0, 400.0, CurtailPolicy::PriceFeedback);
        assert_eq!(b.factor, 1.0);
        assert!((b.buy_mult - 2.0).abs() < 1e-6);
        assert!(b.buy_mult >= 1.0);
    }

    #[test]
    fn headroom_is_normalized_and_clamped() {
        assert_eq!(headroom(0.0, 400.0), 1.0);
        assert_eq!(headroom(-100.0, 400.0), 1.0, "net injection = full headroom");
        assert!((headroom(100.0, 400.0) - 0.75).abs() < 1e-6);
        assert_eq!(headroom(400.0, 400.0), 0.0);
        assert_eq!(headroom(900.0, 400.0), 0.0);
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [CurtailPolicy::Proportional, CurtailPolicy::PriceFeedback] {
            assert_eq!(CurtailPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(CurtailPolicy::parse("curtail-hard"), None);
    }
}
