//! Fused fleet rollout + per-family PPO.
//!
//! [`Fleet::rollout`] is the cross-env analogue of
//! [`VectorEnv::rollout`]: per step it asks the caller's policy for each
//! family's action row, splits **every** family's lanes into shard tasks
//! (shard → (env, lane-range) map from [`Fleet::plan_shards`]), and
//! dispatches all of them in one worker-pool call — heterogeneous
//! stations advance concurrently instead of one pool per env in series.
//! Each shard observes its own lanes right after stepping them, writing
//! straight into that family's [`RolloutBuffers`].
//!
//! [`FleetPpoTrainer`] puts a [`Learner`] (policy + value + Adam) on each
//! family and trains all of them from a single fused rollout per
//! iteration.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::baselines::generalist::{update_generalist_sharded, GeneralistLearner, PolicyRef};
use crate::baselines::mlp::MlpScratch;
use crate::baselines::ppo::{
    update_shard_demand, update_sharded_many, Learner, PpoParams, UpdateBatch,
};
use crate::data::DataStore;
use crate::env::core::{GridBudget, ScenarioTables, StepInfo, DT_HOURS, STEPS_PER_EPISODE};
use crate::env::scalar::ScalarEnv;
use crate::env::tree::StationConfig;
use crate::env::vector::{
    FusedStep, PolicyRollout, RolloutBuffers, ShardTask, StepActs, StepMode, StepOut, VectorEnv,
    BENCH_POLICY_HIDDEN,
};
use crate::runtime::pool::{DisjointTasks, WorkerPool};
use crate::util::rng::Rng;

use super::grid::{self, CurtailPolicy, GridSpec};
use super::{Fleet, FleetSpec};

/// Per-family policy-sampling seed: mixes the iteration seed with the
/// family index so families never share per-(lane, t) action-noise
/// streams (two same-shaped families would otherwise draw identical
/// noise for matching lane indices).
pub fn family_policy_seed(base: u64, family: usize) -> u64 {
    base ^ (family as u64).wrapping_mul(0x9e3779b97f4a7c15)
}

impl Fleet {
    /// Advance every family `n_steps` times in lockstep, writing each
    /// family's observations/rewards/dones/profits into its own
    /// [`RolloutBuffers`] (`bufs[e]`, laid out exactly as
    /// [`VectorEnv::rollout`] expects:
    /// obs `[(T+1) * B_e * obs_dim_e]`, the rest `[T * B_e]`).
    ///
    /// `policy(env, t, obs_t, actions)` reads family `env`'s
    /// `[B_e * obs_dim_e]` observation row for step `t` and fills its
    /// `[B_e * n_ports_e]` action row; policies run on the caller thread,
    /// stepping+observing runs sharded across the fleet-wide pool.
    ///
    /// Bit-identical to rolling the member envs out independently, for
    /// any thread count (lane RNG is counter-based; shard placement never
    /// changes what a lane computes).
    pub fn rollout<F>(&mut self, n_steps: usize, bufs: &mut [RolloutBuffers<'_>], mut policy: F)
    where
        F: FnMut(usize, usize, &[f32], &mut [usize]),
    {
        let n = self.n_envs();
        assert_eq!(bufs.len(), n, "need one RolloutBuffers per fleet env");
        let dims: Vec<(usize, usize, usize)> = (0..n)
            .map(|e| {
                let env = self.env(e);
                (env.batch(), env.n_ports(), env.obs_dim())
            })
            .collect();
        for (e, (&(b, _, d), buf)) in dims.iter().zip(bufs.iter()).enumerate() {
            assert_eq!(buf.obs.len(), (n_steps + 1) * b * d, "env {e}: obs must be [(T+1)*B*obs_dim]");
            assert_eq!(buf.rewards.len(), n_steps * b, "env {e}: rewards must be [T*B]");
            assert_eq!(buf.dones.len(), n_steps * b, "env {e}: dones must be [T*B]");
            assert_eq!(buf.profits.len(), n_steps * b, "env {e}: profits must be [T*B]");
        }
        let plan = self.plan_shards();
        let total: usize = plan.iter().sum();
        // `--threads` is a hard concurrency cap: the pool is sized to it,
        // and when the fleet has more shard tasks than pool lanes the
        // dispatcher strides tasks over the lanes instead of widening the
        // pool. `threads == 1` (or a single task) runs fully inline — no
        // worker threads at all.
        let width = total.min(self.threads.max(1));
        let pool = if width > 1 { Some(self.ensure_pool(width)) } else { None };

        let mut actions: Vec<Vec<usize>> =
            dims.iter().map(|&(b, p, _)| vec![0usize; b * p]).collect();
        let mut infos: Vec<Vec<StepInfo>> =
            dims.iter().map(|&(b, _, _)| vec![StepInfo::default(); b]).collect();

        for ((env, buf), &(b, _, d)) in self.envs.iter().zip(bufs.iter_mut()).zip(&dims) {
            env.observe_all(&mut buf.obs[..b * d]);
        }
        let mut coupling = Coupling::plan(self);
        for t in 0..n_steps {
            match &mut coupling {
                // No coupled family: the pre-coupling single dispatch,
                // byte for byte. Policies first (serial, caller thread),
                // then one pooled call covering every family's shard
                // tasks.
                None => {
                    let mut tasks = Vec::with_capacity(total);
                    for ((((env_idx, env), buf), act), info) in self
                        .envs
                        .iter_mut()
                        .enumerate()
                        .zip(bufs.iter_mut())
                        .zip(actions.iter_mut())
                        .zip(infos.iter_mut())
                    {
                        let (b, _, d) = dims[env_idx];
                        let (obs_t, obs_rest) = buf.obs[t * b * d..].split_at_mut(b * d);
                        policy(env_idx, t, obs_t, act);
                        let out = StepOut {
                            obs: &mut obs_rest[..b * d],
                            rewards: &mut buf.rewards[t * b..(t + 1) * b],
                            dones: &mut buf.dones[t * b..(t + 1) * b],
                            profits: &mut buf.profits[t * b..(t + 1) * b],
                        };
                        let acts = StepActs::Given(act.as_slice());
                        tasks.extend(env.shard_tasks(acts, info, Some(out), plan[env_idx]));
                    }
                    run_fleet_tasks(pool.as_deref(), &mut tasks);
                }
                // Coupled fleet: propose → allocate → commit. Coupled
                // envs stage their currents and report proposed draws in
                // phase one; uncoupled envs run their normal full step in
                // the SAME dispatch (they never wait on the reduce).
                Some(cp) => {
                    let mut tasks = Vec::with_capacity(total);
                    for ((((((env_idx, env), buf), act), info), kw_e), ex_e) in self
                        .envs
                        .iter_mut()
                        .enumerate()
                        .zip(bufs.iter_mut())
                        .zip(actions.iter_mut())
                        .zip(infos.iter_mut())
                        .zip(cp.kw.iter_mut())
                        .zip(cp.excess.iter_mut())
                    {
                        let (b, _, d) = dims[env_idx];
                        let (obs_t, obs_rest) = buf.obs[t * b * d..].split_at_mut(b * d);
                        policy(env_idx, t, obs_t, act);
                        let acts = StepActs::Given(act.as_slice());
                        if kw_e.is_empty() {
                            let out = StepOut {
                                obs: &mut obs_rest[..b * d],
                                rewards: &mut buf.rewards[t * b..(t + 1) * b],
                                dones: &mut buf.dones[t * b..(t + 1) * b],
                                profits: &mut buf.profits[t * b..(t + 1) * b],
                            };
                            tasks.extend(env.shard_tasks(acts, info, Some(out), plan[env_idx]));
                        } else {
                            tasks.extend(env.shard_tasks_mode(
                                acts,
                                &mut [],
                                None,
                                plan[env_idx],
                                StepMode::Propose { grid_kw: kw_e, excess: ex_e },
                            ));
                        }
                    }
                    run_fleet_tasks(pool.as_deref(), &mut tasks);
                    cp.allocate(&mut self.envs);
                    let mut tasks = Vec::with_capacity(total);
                    for (((env_idx, env), buf), info) in self
                        .envs
                        .iter_mut()
                        .enumerate()
                        .zip(bufs.iter_mut())
                        .zip(infos.iter_mut())
                    {
                        if !cp.is_coupled(env_idx) {
                            continue;
                        }
                        let (b, _, d) = dims[env_idx];
                        let (_, obs_rest) = buf.obs[t * b * d..].split_at_mut(b * d);
                        let out = StepOut {
                            obs: &mut obs_rest[..b * d],
                            rewards: &mut buf.rewards[t * b..(t + 1) * b],
                            dones: &mut buf.dones[t * b..(t + 1) * b],
                            profits: &mut buf.profits[t * b..(t + 1) * b],
                        };
                        tasks.extend(env.shard_tasks_mode(
                            StepActs::Committed,
                            info,
                            Some(out),
                            plan[env_idx],
                            StepMode::Commit {
                                budget: cp.budgets[env_idx],
                                excess: &cp.excess[env_idx],
                            },
                        ));
                    }
                    run_fleet_tasks(pool.as_deref(), &mut tasks);
                }
            }
        }
    }

    /// Fused-policy fleet rollout: the cross-env analogue of
    /// [`VectorEnv::rollout_fused`]. Per step, ONE pooled dispatch covers
    /// every family's forward+step shard tasks — each shard forwards +
    /// samples its own lanes with family `e`'s learner (shared-read
    /// weights, per-shard scratch, per-(lane, t) counter RNG seeded by
    /// [`family_policy_seed`]`(policy_seed, e)`), then steps and observes
    /// them, honoring the `--threads` cap via the same strided dispatcher
    /// as [`Fleet::rollout`]. No policy work runs serially on the caller.
    ///
    /// Bit-identical to calling `rollout_fused` on each member env
    /// independently with the same learners and per-family seeds, for any
    /// thread count (proven in rust/tests/fleet.rs).
    pub fn rollout_fused(
        &mut self,
        n_steps: usize,
        bufs: &mut [RolloutBuffers<'_>],
        pols: &mut [PolicyRollout<'_>],
        learners: &[Learner],
        policy_seed: u64,
        greedy: bool,
    ) {
        let policies: Vec<PolicyRef<'_>> =
            learners.iter().map(PolicyRef::PerFamily).collect();
        self.rollout_fused_with(n_steps, bufs, pols, &policies, policy_seed, greedy);
    }

    /// [`Fleet::rollout_fused`] generalized over the policy source:
    /// `policies[e]` is family `e`'s view of whatever drives the fleet — a
    /// per-family [`Learner`] or the shared-trunk generalist (one
    /// [`GeneralistLearner`] viewed per family via
    /// [`PolicyRef::Generalist`], so ONE set of trunk weights serves every
    /// family's shard blocks in the same fused dispatch). Seeding, shard
    /// planning, and the bitwise thread-count contract are identical to
    /// the per-family path.
    pub fn rollout_fused_with(
        &mut self,
        n_steps: usize,
        bufs: &mut [RolloutBuffers<'_>],
        pols: &mut [PolicyRollout<'_>],
        policies: &[PolicyRef<'_>],
        policy_seed: u64,
        greedy: bool,
    ) {
        let n = self.n_envs();
        assert_eq!(bufs.len(), n, "need one RolloutBuffers per fleet env");
        assert_eq!(pols.len(), n, "need one PolicyRollout per fleet env");
        assert_eq!(policies.len(), n, "need one policy view per fleet env");
        let dims: Vec<(usize, usize, usize)> = (0..n)
            .map(|e| {
                let env = self.env(e);
                (env.batch(), env.n_ports(), env.obs_dim())
            })
            .collect();
        for (e, (&(b, p, d), (buf, pol))) in
            dims.iter().zip(bufs.iter().zip(pols.iter())).enumerate()
        {
            assert_eq!(buf.obs.len(), (n_steps + 1) * b * d, "env {e}: obs must be [(T+1)*B*obs_dim]");
            assert_eq!(buf.rewards.len(), n_steps * b, "env {e}: rewards must be [T*B]");
            assert_eq!(buf.dones.len(), n_steps * b, "env {e}: dones must be [T*B]");
            assert_eq!(buf.profits.len(), n_steps * b, "env {e}: profits must be [T*B]");
            assert_eq!(pol.actions.len(), n_steps * b * p, "env {e}: actions must be [T*B*P]");
            assert_eq!(pol.logp.len(), n_steps * b, "env {e}: logp must be [T*B]");
            assert_eq!(pol.values.len(), n_steps * b, "env {e}: values must be [T*B]");
            assert_eq!(policies[e].obs_dim(), d, "env {e}: policy obs_dim mismatch");
            assert_eq!(policies[e].n_ports(), p, "env {e}: policy n_ports mismatch");
        }
        let plan = self.plan_shards();
        let total: usize = plan.iter().sum();
        let width = total.min(self.threads.max(1));
        let pool = if width > 1 { Some(self.ensure_pool(width)) } else { None };

        let mut infos: Vec<Vec<StepInfo>> =
            dims.iter().map(|&(b, _, _)| vec![StepInfo::default(); b]).collect();
        // One forward scratch per planned shard of each family, allocated
        // once and reused every step.
        let mut scratch: Vec<Vec<MlpScratch>> = plan
            .iter()
            .zip(policies)
            .map(|(&s, l)| (0..s.max(1)).map(|_| l.make_scratch()).collect())
            .collect();

        for ((env, buf), &(b, _, d)) in self.envs.iter().zip(bufs.iter_mut()).zip(&dims) {
            env.observe_all(&mut buf.obs[..b * d]);
        }
        let mut coupling = Coupling::plan(self);
        for t in 0..n_steps {
            // Phase one: every family forwards + samples inside its shard
            // tasks. Coupled envs stage currents and report proposed
            // draws (their policy buffers for step `t` are written here,
            // nothing is committed); uncoupled envs take their normal
            // full step in the same dispatch.
            let mut tasks = Vec::with_capacity(total);
            match &mut coupling {
                None => {
                    for (((((env_idx, env), buf), pol), info), scr) in self
                        .envs
                        .iter_mut()
                        .enumerate()
                        .zip(bufs.iter_mut())
                        .zip(pols.iter_mut())
                        .zip(infos.iter_mut())
                        .zip(scratch.iter_mut())
                    {
                        let (b, p, d) = dims[env_idx];
                        let (obs_t, obs_rest) = buf.obs[t * b * d..].split_at_mut(b * d);
                        let fused = FusedStep {
                            learner: policies[env_idx],
                            seed: family_policy_seed(policy_seed, env_idx),
                            t,
                            greedy,
                            obs_t: &*obs_t,
                            actions: &mut pol.actions[t * b * p..(t + 1) * b * p],
                            logp: &mut pol.logp[t * b..(t + 1) * b],
                            values: &mut pol.values[t * b..(t + 1) * b],
                            scratch: scr.as_mut_slice(),
                        };
                        let out = StepOut {
                            obs: &mut obs_rest[..b * d],
                            rewards: &mut buf.rewards[t * b..(t + 1) * b],
                            dones: &mut buf.dones[t * b..(t + 1) * b],
                            profits: &mut buf.profits[t * b..(t + 1) * b],
                        };
                        let acts = StepActs::Fused(fused);
                        tasks.extend(env.shard_tasks(acts, info, Some(out), plan[env_idx]));
                    }
                }
                Some(cp) => {
                    for (((((((env_idx, env), buf), pol), info), scr), kw_e), ex_e) in self
                        .envs
                        .iter_mut()
                        .enumerate()
                        .zip(bufs.iter_mut())
                        .zip(pols.iter_mut())
                        .zip(infos.iter_mut())
                        .zip(scratch.iter_mut())
                        .zip(cp.kw.iter_mut())
                        .zip(cp.excess.iter_mut())
                    {
                        let (b, p, d) = dims[env_idx];
                        let (obs_t, obs_rest) = buf.obs[t * b * d..].split_at_mut(b * d);
                        let fused = FusedStep {
                            learner: policies[env_idx],
                            seed: family_policy_seed(policy_seed, env_idx),
                            t,
                            greedy,
                            obs_t: &*obs_t,
                            actions: &mut pol.actions[t * b * p..(t + 1) * b * p],
                            logp: &mut pol.logp[t * b..(t + 1) * b],
                            values: &mut pol.values[t * b..(t + 1) * b],
                            scratch: scr.as_mut_slice(),
                        };
                        let acts = StepActs::Fused(fused);
                        if kw_e.is_empty() {
                            let out = StepOut {
                                obs: &mut obs_rest[..b * d],
                                rewards: &mut buf.rewards[t * b..(t + 1) * b],
                                dones: &mut buf.dones[t * b..(t + 1) * b],
                                profits: &mut buf.profits[t * b..(t + 1) * b],
                            };
                            tasks.extend(env.shard_tasks(acts, info, Some(out), plan[env_idx]));
                        } else {
                            tasks.extend(env.shard_tasks_mode(
                                acts,
                                &mut [],
                                None,
                                plan[env_idx],
                                StepMode::Propose { grid_kw: kw_e, excess: ex_e },
                            ));
                        }
                    }
                }
            }
            run_fleet_tasks(pool.as_deref(), &mut tasks);
            let Some(cp) = &mut coupling else { continue };
            cp.allocate(&mut self.envs);
            // Phase two: commit the coupled lanes under their feeder
            // budgets (no action source — currents were staged in phase
            // one; headroom was just refreshed by the allocate).
            let mut tasks = Vec::with_capacity(total);
            for (((env_idx, env), buf), info) in self
                .envs
                .iter_mut()
                .enumerate()
                .zip(bufs.iter_mut())
                .zip(infos.iter_mut())
            {
                if !cp.is_coupled(env_idx) {
                    continue;
                }
                let (b, _, d) = dims[env_idx];
                let (_, obs_rest) = buf.obs[t * b * d..].split_at_mut(b * d);
                let out = StepOut {
                    obs: &mut obs_rest[..b * d],
                    rewards: &mut buf.rewards[t * b..(t + 1) * b],
                    dones: &mut buf.dones[t * b..(t + 1) * b],
                    profits: &mut buf.profits[t * b..(t + 1) * b],
                };
                tasks.extend(env.shard_tasks_mode(
                    StepActs::Committed,
                    info,
                    Some(out),
                    plan[env_idx],
                    StepMode::Commit {
                        budget: cp.budgets[env_idx],
                        excess: &cp.excess[env_idx],
                    },
                ));
            }
            run_fleet_tasks(pool.as_deref(), &mut tasks);
        }
    }
}

/// Dispatch one step's shard tasks (from all families) over at most
/// `pool.max_shards()` concurrent lanes: pool lane `s` runs tasks
/// `s, s + width, s + 2·width, ...` serially. This is what lets the fleet
/// honor a `--threads` cap smaller than its task count — the per-env
/// runtime never queues more shards than threads, so it has no such path.
/// Without a pool (or with a single task) everything runs inline on the
/// caller thread. Task-to-lane placement never changes what a task
/// computes, so results are identical for any width.
fn run_fleet_tasks(pool: Option<&WorkerPool>, tasks: &mut [ShardTask<'_>]) {
    match pool {
        Some(pool) if tasks.len() > 1 && pool.max_shards() > 1 => {
            let shared = DisjointTasks::new(tasks);
            // SAFETY: `run_strided` visits task index `k` exactly once, so
            // each access is exclusive — no locks on the hot path.
            pool.run_strided(shared.len(), |_, k| unsafe { shared.get(k) }.run());
        }
        _ => {
            for task in tasks {
                task.run();
            }
        }
    }
}

/// Per-step scratch + plan for a feeder-coupled fleet's allocate phase
/// (built once per rollout, reused every step). Uncoupled envs carry
/// empty proposal buffers — `is_coupled` keys off that — and always keep
/// [`GridBudget::UNCURTAILED`].
struct Coupling {
    /// `(resolved capacity kW, spec, member env indices)` per distinct
    /// feeder, in deterministic first-appearance env order (from
    /// [`Fleet::coupling_groups`]). The capacity is resolved ONCE here, at
    /// plan time: [`Fleet::set_grids`] already rejected doc-only
    /// (`capacity_kw: null`) and non-finite entries at spec-load time with
    /// a named error, so the old rollout-time
    /// `spec.capacity_kw.expect(...)` panic path is gone — groups without
    /// a concrete capacity simply never enter the plan.
    groups: Vec<(f32, GridSpec, Vec<usize>)>,
    /// Per-env proposed grid draw (kW) per lane; empty for uncoupled envs.
    kw: Vec<Vec<f32>>,
    /// Per-env staged pre-projection excess (kW) per lane.
    excess: Vec<Vec<f32>>,
    /// Per-env allocation for the current step.
    budgets: Vec<GridBudget>,
    /// Group-concat scratch for the fixed-order reduce.
    concat: Vec<f32>,
}

impl Coupling {
    /// `None` when the fleet has no coupled family — the caller keeps the
    /// pre-coupling single-dispatch step byte for byte.
    fn plan(fleet: &Fleet) -> Option<Coupling> {
        if !fleet.has_coupling() {
            return None;
        }
        let n = fleet.n_envs();
        let lanes = |e: usize| {
            if fleet.grid(e).is_some_and(GridSpec::coupled) { fleet.env(e).batch() } else { 0 }
        };
        let groups = fleet
            .coupling_groups()
            .into_iter()
            .filter_map(|(spec, members)| spec.capacity_kw.map(|cap| (cap, spec, members)))
            .collect();
        Some(Coupling {
            groups,
            kw: (0..n).map(|e| vec![0.0; lanes(e)]).collect(),
            excess: (0..n).map(|e| vec![0.0; lanes(e)]).collect(),
            budgets: vec![GridBudget::UNCURTAILED; n],
            concat: Vec::new(),
        })
    }

    fn is_coupled(&self, e: usize) -> bool {
        !self.kw[e].is_empty()
    }

    /// The allocate phase: per coupling group, sum the proposed draws
    /// with the fixed-order tree reduce (member lanes concatenated in env
    /// order — NEVER per-shard partials, so the f32 total is identical at
    /// any `--threads`), pick the group's budget, and publish the
    /// feeder-headroom obs value to every member env. One `grid-reduce`
    /// telemetry span covers all groups of the step; over-capacity
    /// proportional curtailment accrues the `curtailed_kwh` counter.
    fn allocate(&mut self, envs: &mut [VectorEnv]) {
        let _span = crate::telemetry::scope(crate::telemetry::SpanKind::GridReduce);
        let recording = crate::telemetry::recording();
        for (cap, spec, members) in &self.groups {
            let cap = *cap;
            self.concat.clear();
            for &e in members {
                self.concat.extend_from_slice(&self.kw[e]);
            }
            let total = grid::reduce_proposals(&self.concat);
            let budget = grid::allocate(total, cap, spec.policy);
            let head = grid::headroom(total, cap);
            if recording && spec.policy == CurtailPolicy::Proportional {
                let curtailed = ((total - cap).max(0.0) * DT_HOURS) as f64;
                if curtailed > 0.0 {
                    crate::telemetry::counters(|c| c.curtailed_kwh += curtailed);
                }
            }
            for &e in members {
                self.budgets[e] = budget;
                envs[e].set_grid_headroom(head);
            }
        }
    }
}

/// Per-family rollout storage for one PPO iteration (env-written half).
struct EnvBufs {
    obs: Vec<f32>,
    rew: Vec<f32>,
    done: Vec<f32>,
    profit: Vec<f32>,
}

impl EnvBufs {
    fn new(b: usize, d: usize, t_len: usize) -> EnvBufs {
        EnvBufs {
            obs: vec![0.0; (t_len + 1) * b * d],
            rew: vec![0.0; t_len * b],
            done: vec![0.0; t_len * b],
            profit: vec![0.0; t_len * b],
        }
    }

    fn as_rollout_buffers(&mut self) -> RolloutBuffers<'_> {
        RolloutBuffers {
            obs: &mut self.obs,
            rewards: &mut self.rew,
            dones: &mut self.done,
            profits: &mut self.profit,
        }
    }
}

/// Per-family rollout storage for one PPO iteration (policy-written half:
/// sampled actions, log-probs, values).
struct PolBufs {
    act: Vec<usize>,
    logp: Vec<f32>,
    val: Vec<f32>,
}

impl PolBufs {
    fn new(b: usize, p: usize, t_len: usize) -> PolBufs {
        PolBufs {
            act: vec![0usize; t_len * b * p],
            logp: vec![0.0; t_len * b],
            val: vec![0.0; t_len * b],
        }
    }

    fn as_policy_rollout(&mut self) -> PolicyRollout<'_> {
        PolicyRollout {
            actions: &mut self.act,
            logp: &mut self.logp,
            values: &mut self.val,
        }
    }
}

/// One slot of the trainer's double buffer: every family's env-written and
/// policy-written rollout storage for one iteration. With `--overlap on`
/// two slots ping-pong — the caller consumes slot `cur` (PPO update,
/// accounting, stats, interleaved eval) while the pool's pipeline lane
/// streams the NEXT iteration's fused rollout into the other slot. All
/// buffers are fully overwritten by each rollout, so reuse is bitwise
/// inert.
struct IterSlot {
    eb: Vec<EnvBufs>,
    pb: Vec<PolBufs>,
}

impl IterSlot {
    fn new(dims: &[(usize, usize, usize)], t_len: usize) -> IterSlot {
        IterSlot {
            eb: dims.iter().map(|&(b, _, d)| EnvBufs::new(b, d, t_len)).collect(),
            pb: dims.iter().map(|&(b, p, _)| PolBufs::new(b, p, t_len)).collect(),
        }
    }
}

/// Everything one family's greedy per-cell eval reads from the fleet,
/// snapshotted up front (cheap: config copies + `Arc` table clones) so
/// eval can run on the caller thread while the pipeline lane holds the
/// fleet's `&mut` for the streaming rollout. Built by
/// [`FleetPpoTrainer::eval_plan`]; consumed by `run_eval_family` — the
/// ONE eval implementation behind both [`FleetPpoTrainer::eval_cells`]
/// and the overlapped window, so the two paths cannot drift.
struct EvalPlan {
    family: String,
    family_idx: usize,
    cfg: StationConfig,
    /// Trained cells in cell-index order: `(name, tables, training lanes)`.
    cells: Vec<(String, Arc<ScenarioTables>, usize)>,
    /// Held-out cells (zero training lanes, `holdout == true` in output).
    holdout: Vec<(String, Arc<ScenarioTables>)>,
}

/// Greedy eval of one family from its snapshot: one fresh B=1 scalar env
/// per cell, one full episode each, trained cells then held-out cells
/// (cell indices continue past the trained cells so eval seeds never
/// collide). Byte-for-byte the body `eval_cells` always had.
fn run_eval_family(plan: &EvalPlan, pol: PolicyRef<'_>, seed: u64) -> Vec<CellEval> {
    let _span = crate::telemetry::scope(crate::telemetry::SpanKind::Eval);
    let mut scratch = pol.make_scratch();
    let mut obs = vec![0f32; pol.obs_dim()];
    let mut action = vec![0usize; pol.n_ports()];
    let mut out = Vec::with_capacity(plan.cells.len() + plan.holdout.len());
    let mut run_cell = |cell: usize, tables: Arc<ScenarioTables>, name: String, lanes: usize, held: bool| {
        // Decorrelate cells without losing seed-level reproducibility.
        let env_seed = seed ^ ((cell as u64) << 32);
        let mut env = ScalarEnv::new(plan.cfg.clone(), tables, env_seed);
        let mut tot_r = 0f32;
        let mut tot_p = 0f32;
        let mut episodes = 0usize;
        for _ in 0..STEPS_PER_EPISODE {
            env.observe(&mut obs);
            pol.greedy_lane(&obs, &mut action, &mut scratch);
            let info = env.step(&action);
            tot_r += info.reward;
            tot_p += info.profit;
            if info.done {
                episodes += 1;
            }
        }
        out.push(CellEval {
            family: plan.family.clone(),
            family_idx: plan.family_idx,
            cell: name,
            cell_idx: cell,
            lanes,
            holdout: held,
            episodes,
            reward: tot_r,
            profit: tot_p,
        });
    };
    for (cell, (name, tables, lanes)) in plan.cells.iter().enumerate() {
        run_cell(cell, Arc::clone(tables), name.clone(), *lanes, false);
    }
    // Held-out cells continue the cell index space after the trained
    // cells, so their eval seeds never collide with a trained cell's.
    for (i, (name, tables)) in plan.holdout.iter().enumerate() {
        run_cell(plan.cells.len() + i, Arc::clone(tables), name.clone(), 0, true);
    }
    out
}

/// Per-iteration training stats for one station family.
pub struct FamilyStats {
    pub label: String,
    pub lanes: usize,
    pub mean_reward: f32,
    pub mean_profit: f32,
    pub total_loss: f32,
    pub entropy: f32,
    pub completed_return_mean: f32,
}

/// What drives a fleet: one isolated [`Learner`] per station family (the
/// original oracle path, `--policy per-family`), or ONE shared-trunk
/// [`GeneralistLearner`] whose trunk serves every family and whose
/// per-family heads decode each family's action space
/// (`--policy generalist`).
pub enum FleetPolicy {
    PerFamily(Vec<Learner>),
    Generalist(GeneralistLearner),
}

impl FleetPolicy {
    /// Family `e`'s read-only policy view — what the fused rollout and
    /// greedy eval dispatch through.
    pub fn family(&self, e: usize) -> PolicyRef<'_> {
        match self {
            FleetPolicy::PerFamily(ls) => PolicyRef::PerFamily(&ls[e]),
            FleetPolicy::Generalist(g) => PolicyRef::Generalist(g, e),
        }
    }

    /// Every parameter of every net, flattened in a deterministic order —
    /// what the thread-count-invariance tests compare bitwise.
    pub fn params_flat(&self) -> Vec<f32> {
        match self {
            FleetPolicy::PerFamily(ls) => ls
                .iter()
                .flat_map(|l| {
                    l.mlp.params().into_iter().flat_map(|p| p.iter().copied()).collect::<Vec<_>>()
                })
                .collect(),
            FleetPolicy::Generalist(g) => {
                g.params().into_iter().flat_map(|p| p.iter().copied()).collect()
            }
        }
    }

    /// The per-family learners, when this is the per-family path (tests
    /// and the oracle comparisons use this; the generalist has no
    /// per-family nets to hand out).
    pub fn per_family(&self) -> Option<&[Learner]> {
        match self {
            FleetPolicy::PerFamily(ls) => Some(ls),
            FleetPolicy::Generalist(_) => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FleetPolicy::PerFamily(_) => "per-family",
            FleetPolicy::Generalist(_) => "generalist",
        }
    }
}

/// PPO over a fleet: a [`FleetPolicy`] (per-family learners or one
/// shared-trunk generalist) rolled out over all families in one fused
/// [`Fleet::rollout_fused_with`] pass per iteration, then updated through
/// one pooled sharded update.
pub struct FleetPpoTrainer {
    pub hp: PpoParams,
    pub fleet: Fleet,
    pub policy: FleetPolicy,
    pub rng: Rng,
    pub env_steps: usize,
    /// Per-family, per-lane running episode returns (same accounting as
    /// `PpoTrainer`).
    running_return: Vec<Vec<f32>>,
    /// The current iteration's greedy-eval seed, drawn from the trainer
    /// rng once per iteration (and once at construction). Evals used to
    /// depend entirely on whatever ad-hoc seed each caller invented per
    /// call, so two evals "of the same iteration" could disagree; routing
    /// them through this one per-iteration draw makes repeated
    /// [`FleetPpoTrainer::eval_cells_current`] calls bit-identical until
    /// the next `iteration()` advances it.
    eval_seed: u64,
    /// Double-buffer slots, allocated lazily (one for barrier mode, two
    /// once overlap ever prefetches) and reused every iteration.
    slots: Vec<IterSlot>,
    /// Which slot the next update consumes. The other slot (when it
    /// exists) is the pipelined prefetch target.
    cur: usize,
    /// True when slot `cur` already holds the next iteration's rollout
    /// (streamed by the previous iteration's overlap window), so
    /// `iteration()` skips its synchronous rollout and goes straight to
    /// the update.
    pending: bool,
}

impl FleetPpoTrainer {
    /// `hp.num_envs` is ignored — the fleet's lane counts come from its
    /// spec; everything else (lr, rollout length, epochs, ...) is shared
    /// across families.
    pub fn new(hp: PpoParams, fleet: Fleet, seed: u64) -> FleetPpoTrainer {
        let mut rng = Rng::new(seed);
        let learners: Vec<Learner> = (0..fleet.n_envs())
            .map(|e| {
                let env = fleet.env(e);
                Learner::new(&mut rng, env.obs_dim(), hp.hidden, env.action_nvec())
            })
            .collect();
        let running_return =
            (0..fleet.n_envs()).map(|e| vec![0.0; fleet.env(e).batch()]).collect();
        // Drawn AFTER the learners so their init matches older builds.
        let eval_seed = rng.next_u64();
        FleetPpoTrainer {
            hp,
            fleet,
            policy: FleetPolicy::PerFamily(learners),
            rng,
            env_steps: 0,
            running_return,
            eval_seed,
            slots: Vec::new(),
            cur: 0,
            pending: false,
        }
    }

    /// Trainer with ONE shared-trunk generalist across the whole scenario
    /// grid (`--policy generalist`): trunk input is the fleet's
    /// [`GridShape`](crate::fleet::GridShape) — obs padded to the
    /// grid-wide max dim plus a family one-hot — with per-family action
    /// heads and a shared value head.
    pub fn new_generalist(hp: PpoParams, fleet: Fleet, seed: u64) -> FleetPpoTrainer {
        let mut rng = Rng::new(seed);
        let shape = fleet.grid_shape();
        let gen =
            GeneralistLearner::new(&mut rng, shape.pad_obs, hp.hidden, &shape.learner_specs());
        let running_return =
            (0..fleet.n_envs()).map(|e| vec![0.0; fleet.env(e).batch()]).collect();
        let eval_seed = rng.next_u64();
        FleetPpoTrainer {
            hp,
            fleet,
            policy: FleetPolicy::Generalist(gen),
            rng,
            env_steps: 0,
            running_return,
            eval_seed,
            slots: Vec::new(),
            cur: 0,
            pending: false,
        }
    }

    /// Env steps consumed by one `iteration` (all families).
    pub fn steps_per_iteration(&self) -> usize {
        self.fleet.total_lanes() * self.hp.rollout_steps
    }

    /// One fused rollout + one PPO update per family. With `hp.overlap`
    /// set, the NEXT iteration's rollout is prefetched on the pool's
    /// pipeline lane while this call finishes its accounting and stats
    /// (use [`FleetPpoTrainer::final_iteration`] for the last call of a
    /// run). Results are bit-identical either way: the per-iteration rng
    /// draw order — policy seed, update permutations, eval seed — forms
    /// the same global sequence in both modes; only WHEN each rollout
    /// executes moves (proven in rust/tests/overlap.rs).
    pub fn iteration(&mut self) -> Vec<FamilyStats> {
        let overlap = self.hp.overlap;
        self.iteration_inner(overlap, None)
    }

    /// [`FleetPpoTrainer::iteration`] without the trailing prefetch: call
    /// this for the LAST iteration of a run so exactly N rollouts execute
    /// for N iterations (a trailing prefetch would roll the envs forward
    /// one extra rollout that no one consumes). Identical to
    /// `iteration()` when overlap is off.
    pub fn final_iteration(&mut self) -> Vec<FamilyStats> {
        self.iteration_inner(false, None)
    }

    /// One iteration PLUS this iteration's full per-cell greedy eval
    /// (every family, trained + held-out cells, keyed by the iteration's
    /// eval seed). With overlap on, the eval episodes run on the caller
    /// thread INSIDE the overlap window — interleaved with the streaming
    /// next-iteration rollout — and are bit-identical to calling
    /// `iteration()` then [`FleetPpoTrainer::eval_all_cells_current`]
    /// (the per-iteration eval seed makes the ordering irrelevant;
    /// regression-tested in rust/tests/overlap.rs).
    pub fn iteration_with_eval(&mut self) -> (Vec<FamilyStats>, Vec<CellEval>) {
        let overlap = self.hp.overlap;
        let mut evals = Vec::new();
        let stats = self.iteration_inner(overlap, Some(&mut evals));
        (stats, evals)
    }

    fn iteration_inner(
        &mut self,
        prefetch: bool,
        evals: Option<&mut Vec<CellEval>>,
    ) -> Vec<FamilyStats> {
        let t_len = self.hp.rollout_steps;
        let n = self.fleet.n_envs();
        let dims: Vec<(usize, usize, usize)> = (0..n)
            .map(|e| {
                let env = self.fleet.env(e);
                (env.batch(), env.n_ports(), env.obs_dim())
            })
            .collect();
        let want_slots = if prefetch { 2 } else { 1 };
        while self.slots.len() < want_slots {
            self.slots.push(IterSlot::new(&dims, t_len));
        }

        if !self.pending {
            // Fused-policy pass: every family's forward+step shard tasks
            // go out in one pooled dispatch per step; a fresh
            // per-iteration seed keys the per-(lane, t) counter streams.
            // Under the generalist, every family's view shares one set of
            // trunk weights — still a single dispatch per step. With
            // overlap on this branch only runs for the FIRST iteration —
            // afterwards every rollout arrives prefetched in slot `cur`.
            let _span = crate::telemetry::scope(crate::telemetry::SpanKind::Rollout);
            let FleetPpoTrainer { fleet, policy, rng, slots, cur, .. } = &mut *self;
            let slot = &mut slots[*cur];
            let policy_seed = rng.next_u64();
            let mut bufs: Vec<RolloutBuffers<'_>> =
                slot.eb.iter_mut().map(EnvBufs::as_rollout_buffers).collect();
            let mut pols: Vec<PolicyRollout<'_>> =
                slot.pb.iter_mut().map(PolBufs::as_policy_rollout).collect();
            let views: Vec<PolicyRef<'_>> = (0..n).map(|e| policy.family(e)).collect();
            fleet.rollout_fused_with(t_len, &mut bufs, &mut pols, &views, policy_seed, false);
        }
        self.pending = false;
        self.env_steps += self.fleet.total_lanes() * t_len;

        // One sharded update covering EVERY family: per (epoch,
        // minibatch) round all families' gradient chunks go out in a
        // single pooled dispatch (strided over at most `--threads`
        // lanes), so the pool never idles between families the way
        // serial per-family updates left it. Bit-identical to those
        // serial updates for any thread count. The generalist goes one
        // further — its round's chunks from ALL families reduce through
        // one fixed-order pairwise tree into a single Adam step on the
        // shared trunk.
        let width: usize = dims
            .iter()
            .map(|&(b, _, _)| update_shard_demand(b * t_len, self.hp.n_minibatches))
            .sum();
        let pool = self.fleet.update_pool(width);
        let upd = {
            let FleetPpoTrainer { hp, policy, rng, slots, cur, .. } = &mut *self;
            let slot = &slots[*cur];
            let batches: Vec<UpdateBatch<'_>> = (0..n)
                .map(|e| UpdateBatch {
                    n_envs: dims[e].0,
                    t_len,
                    obs: &slot.eb[e].obs,
                    act: &slot.pb[e].act,
                    logp: &slot.pb[e].logp,
                    val: &slot.pb[e].val,
                    rew: &slot.eb[e].rew,
                    done: &slot.eb[e].done,
                })
                .collect();
            match policy {
                FleetPolicy::PerFamily(learners) => {
                    update_sharded_many(learners, hp, rng, pool.as_deref(), &batches)
                }
                FleetPolicy::Generalist(gen) => {
                    update_generalist_sharded(gen, hp, rng, pool.as_deref(), &batches)
                }
            }
        };
        // Refresh the shared eval seed right after the update so the
        // rollout/update rng stream is untouched and every
        // within-iteration eval repeats — and so the prefetch below
        // (launched AFTER this draw) keeps barrier mode's global draw
        // order: policy seed, update perms, eval seed, next policy seed.
        self.eval_seed = self.rng.next_u64();

        // Snapshot everything the overlap window reads from the fleet
        // BEFORE the pipeline lane takes the fleet's `&mut` for the
        // streaming rollout.
        let labels: Vec<String> = (0..n).map(|e| self.fleet.label(e).to_string()).collect();
        let eval_plans: Vec<EvalPlan> = if evals.is_some() {
            (0..n).map(|e| self.eval_plan(e)).collect()
        } else {
            Vec::new()
        };

        let FleetPpoTrainer {
            fleet, policy, rng, running_return, eval_seed, slots, cur, pending, ..
        } = &mut *self;
        // Launch the next iteration's rollout on the pool's pipeline
        // lane. Skipped when the fleet runs inline (`--threads 1` / tiny
        // fleet): there is no pool to stream on, so the next
        // `iteration()` call simply rolls out synchronously — same draws,
        // same bits, pure barrier semantics.
        let mut guard = None;
        if prefetch {
            let width =
                fleet.plan_shards().iter().sum::<usize>().min(fleet.threads().max(1));
            if width > 1 {
                let pool = fleet.ensure_pool(width);
                let policy_seed = rng.next_u64();
                let (a, b) = slots.split_at_mut(1);
                let next = if *cur == 0 { &mut b[0] } else { &mut a[0] };
                let views: Vec<PolicyRef<'_>> = (0..n).map(|e| policy.family(e)).collect();
                let fleet = &mut *fleet;
                // SAFETY: the guard is joined at the end of this window
                // (never leaked), and until then the caller only touches
                // state disjoint from the closure's captures: slot `cur`
                // (the closure fills the OTHER slot), `running_return`,
                // the label/eval snapshots above, and shared reads of the
                // policy (the closure holds shared `views` too). The
                // fleet is not touched again until after the join.
                guard = Some(unsafe {
                    pool.run_pipelined(move || {
                        let _span =
                            crate::telemetry::scope(crate::telemetry::SpanKind::Rollout);
                        let mut bufs: Vec<RolloutBuffers<'_>> =
                            next.eb.iter_mut().map(EnvBufs::as_rollout_buffers).collect();
                        let mut pols: Vec<PolicyRollout<'_>> =
                            next.pb.iter_mut().map(PolBufs::as_policy_rollout).collect();
                        fleet.rollout_fused_with(
                            t_len, &mut bufs, &mut pols, &views, policy_seed, false,
                        );
                    })
                });
            }
        }

        // The overlap window: episode accounting, per-family stats, and
        // any interleaved eval run on the caller thread while the
        // pipeline lane streams the next rollout. In barrier mode the
        // same code simply runs after the synchronous work, unspanned.
        let _window = guard
            .is_some()
            .then(|| crate::telemetry::scope(crate::telemetry::SpanKind::PipelineOverlap));
        let slot = &slots[*cur];
        let mut out = Vec::with_capacity(n);
        for (e, (total_loss, entropy)) in upd.into_iter().enumerate() {
            let (b, _, _) = dims[e];
            let bsz = b * t_len;
            let mut profit_sum = 0f64;
            let mut comp: Vec<f32> = Vec::new();
            for t in 0..t_len {
                for j in 0..b {
                    let idx = t * b + j;
                    profit_sum += slot.eb[e].profit[idx] as f64;
                    running_return[e][j] += slot.eb[e].rew[idx];
                    if slot.eb[e].done[idx] > 0.5 {
                        comp.push(running_return[e][j]);
                        running_return[e][j] = 0.0;
                    }
                }
            }
            out.push(FamilyStats {
                label: labels[e].clone(),
                lanes: b,
                mean_reward: slot.eb[e].rew.iter().sum::<f32>() / bsz as f32,
                mean_profit: (profit_sum / bsz as f64) as f32,
                total_loss,
                entropy,
                completed_return_mean: if comp.is_empty() {
                    0.0
                } else {
                    comp.iter().sum::<f32>() / comp.len() as f32
                },
            });
        }
        if let Some(evals) = evals {
            // Eval filler: greedy per-cell episodes on the CALLER thread,
            // off the snapshot (pooled eval would grab the dispatch mutex
            // and starve the streaming rollout between its steps).
            for (e, plan) in eval_plans.iter().enumerate() {
                evals.extend(run_eval_family(plan, policy.family(e), *eval_seed));
            }
        }
        if let Some(g) = guard {
            g.join();
            *cur ^= 1;
            *pending = true;
        }
        out
    }

    /// Greedy eval of family `e` on EVERY distinct scenario cell its lanes
    /// train on — one fresh B=1 scalar env per cell (Arc-shared tables),
    /// one full episode each — PLUS every `holdout` cell of the family,
    /// evaluated zero-shot (the planner guarantees no training lane ever
    /// saw one). Each entry names the cell it came from, how many training
    /// lanes run it (0 and `holdout == true` for held-out cells), and how
    /// many eval episodes its reward/profit totals cover, so trained and
    /// held-out cells are comparable on the paper's profit metric.
    pub fn eval_cells(&self, e: usize, seed: u64) -> Vec<CellEval> {
        run_eval_family(&self.eval_plan(e), self.policy.family(e), seed)
    }

    /// Snapshot of everything family `e`'s greedy per-cell eval reads
    /// from the fleet — a config copy plus `Arc` table clones, cheap —
    /// so the overlap window can evaluate on the caller thread while the
    /// pipeline lane holds the fleet's `&mut` for the streaming rollout.
    fn eval_plan(&self, e: usize) -> EvalPlan {
        let fam = self.fleet.env(e);
        let counts = fam.scenario_lane_counts();
        EvalPlan {
            family: self.fleet.label(e).to_string(),
            family_idx: e,
            cfg: fam.cfg.clone(),
            cells: (0..fam.n_scenarios())
                .map(|cell| {
                    (
                        self.fleet.cell_label(e, cell).to_string(),
                        fam.scenario_tables(cell),
                        counts[cell],
                    )
                })
                .collect(),
            holdout: self.fleet.holdout_cells(e).to_vec(),
        }
    }

    /// [`FleetPpoTrainer::eval_cells`] over every family, flattened.
    pub fn eval_all_cells(&self, seed: u64) -> Vec<CellEval> {
        (0..self.fleet.n_envs()).flat_map(|e| self.eval_cells(e, seed)).collect()
    }

    /// The greedy-eval seed for the CURRENT iteration — drawn from the
    /// trainer rng once per `iteration()`, so eval episodes track the
    /// training trajectory while staying repeatable within an iteration.
    pub fn current_eval_seed(&self) -> u64 {
        self.eval_seed
    }

    /// [`FleetPpoTrainer::eval_cells`] keyed by the trainer rng's
    /// per-iteration eval seed: call it as many times as you like between
    /// two `iteration()` calls and every result is bit-identical
    /// (regression-tested in rust/tests/fleet.rs).
    pub fn eval_cells_current(&self, e: usize) -> Vec<CellEval> {
        self.eval_cells(e, self.eval_seed)
    }

    /// [`FleetPpoTrainer::eval_cells_current`] over every family.
    pub fn eval_all_cells_current(&self) -> Vec<CellEval> {
        self.eval_all_cells(self.eval_seed)
    }
}

/// One greedy-eval number with its provenance: which station family and
/// which scenario cell (country × year × traffic × profile) produced it,
/// how many training lanes run that cell (`0` for held-out cells, which
/// also carry `holdout == true`), and how many completed eval episodes
/// the reward/profit totals cover.
#[derive(Debug, Clone)]
pub struct CellEval {
    pub family: String,
    pub family_idx: usize,
    pub cell: String,
    pub cell_idx: usize,
    pub lanes: usize,
    /// True when this cell was carved out of training by the `holdout`
    /// schema key — its numbers are zero-shot.
    pub holdout: bool,
    /// Completed episodes behind `reward`/`profit` (counted from env
    /// dones, so the totals are honestly per-`episodes`, not per-step).
    pub episodes: usize,
    pub reward: f32,
    pub profit: f32,
}

/// Which policy drives a fleet throughput measurement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetBenchPolicy {
    /// Pre-drawn random actions copied per step (env runtime alone).
    Random,
    /// Real per-family MLPs sampled serially on the caller thread via
    /// `sample_row` inside the [`Fleet::rollout`] closure (the pre-fused
    /// training path, kept as the comparator).
    SerialNet,
    /// The same MLPs forwarded + sampled inside the shard tasks
    /// ([`Fleet::rollout_fused`], the default training path).
    FusedNet,
    /// ONE shared-trunk generalist serving every family inside the shard
    /// tasks ([`Fleet::rollout_fused_with`] over
    /// [`PolicyRef::Generalist`] views — padded rows, per-family heads).
    GeneralistNet,
    /// Same fused per-family MLPs as [`FleetBenchPolicy::FusedNet`], but
    /// the caller passes a feeder-coupled spec, so every step pays the
    /// propose → allocate → commit double dispatch. The row pair
    /// (`fleet-policy-fused` vs `fleet-coupled` at matched lanes)
    /// isolates the grid-coupling overhead.
    CoupledNet,
}

impl FleetBenchPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            FleetBenchPolicy::Random => "fleet-rollout",
            FleetBenchPolicy::SerialNet => "fleet-policy-serial",
            FleetBenchPolicy::FusedNet => "fleet-policy-fused",
            FleetBenchPolicy::GeneralistNet => "fleet-generalist",
            FleetBenchPolicy::CoupledNet => "fleet-coupled",
        }
    }
}

/// Measure fused fleet-rollout throughput: one warm pass then one timed
/// pass over fixed-length chunks (same protocol as
/// [`crate::env::vector::measure_throughput`], so fleet rows in
/// BENCH_fleet.json are comparable to the single-env sweep). `policy`
/// picks random actions or real per-family nets (serial vs fused —
/// identical nets, so the row pair isolates where the forward runs).
/// Returns `(env-steps/sec, seconds per 100k env steps, total lanes,
/// families)`.
pub fn measure_fleet_throughput(
    spec: &FleetSpec,
    store: Option<&DataStore>,
    threads: usize,
    budget: usize,
    policy: FleetBenchPolicy,
) -> Result<(f64, f64, usize, usize)> {
    let mut fleet = Fleet::from_spec(spec, store)?;
    fleet.set_threads(threads);
    let n = fleet.n_envs();
    let total_lanes = fleet.total_lanes();
    let t_chunk = 64usize;
    let n_chunks = (budget / (total_lanes * t_chunk).max(1)).clamp(1, 300);
    let dims: Vec<(usize, usize, usize)> = (0..n)
        .map(|e| {
            let env = fleet.env(e);
            (env.batch(), env.n_ports(), env.obs_dim())
        })
        .collect();
    // Only the chosen policy's inputs are built: random action chunks for
    // Random, nets + policy buffers for the two net paths (at scale=16
    // the unused half would be megabytes of dead allocation + RNG work).
    let mut arng = Rng::new(23);
    let actions: Vec<Vec<usize>> = if policy == FleetBenchPolicy::Random {
        (0..n)
            .map(|e| {
                let (b, p, _) = dims[e];
                let nvec = fleet.env(e).action_nvec();
                (0..t_chunk * b * p)
                    .map(|k| arng.below(nvec[k % p] as u32) as usize)
                    .collect()
            })
            .collect()
    } else {
        Vec::new()
    };
    let learners: Vec<Learner> = if matches!(
        policy,
        FleetBenchPolicy::SerialNet | FleetBenchPolicy::FusedNet | FleetBenchPolicy::CoupledNet
    ) {
        (0..n)
            .map(|e| {
                let env = fleet.env(e);
                Learner::new(&mut arng, env.obs_dim(), BENCH_POLICY_HIDDEN, env.action_nvec())
            })
            .collect()
    } else {
        Vec::new()
    };
    let gen: Option<GeneralistLearner> = if policy == FleetBenchPolicy::GeneralistNet {
        let shape = fleet.grid_shape();
        Some(GeneralistLearner::new(
            &mut arng,
            shape.pad_obs,
            BENCH_POLICY_HIDDEN,
            &shape.learner_specs(),
        ))
    } else {
        None
    };
    let mut pb: Vec<PolBufs> = if policy == FleetBenchPolicy::Random {
        Vec::new()
    } else {
        dims.iter().map(|&(b, p, _)| PolBufs::new(b, p, t_chunk)).collect()
    };
    let mut eb: Vec<EnvBufs> =
        dims.iter().map(|&(b, _, d)| EnvBufs::new(b, d, t_chunk)).collect();
    let mut srng = Rng::new(71);
    let mut pass = |fleet: &mut Fleet, eb: &mut [EnvBufs], pb: &mut [PolBufs]| {
        for chunk in 0..n_chunks {
            let mut bufs: Vec<RolloutBuffers<'_>> =
                eb.iter_mut().map(EnvBufs::as_rollout_buffers).collect();
            match policy {
                FleetBenchPolicy::Random => {
                    fleet.rollout(t_chunk, &mut bufs, |e, t, _obs, act| {
                        let (b, p, _) = dims[e];
                        act.copy_from_slice(&actions[e][t * b * p..(t + 1) * b * p]);
                    });
                }
                FleetBenchPolicy::SerialNet => {
                    let learners = &learners;
                    let srng = &mut srng;
                    let pb = &mut *pb;
                    fleet.rollout(t_chunk, &mut bufs, |e, t, obs_t, act| {
                        let (b, p, _) = dims[e];
                        let pbe = &mut pb[e];
                        learners[e].sample_row(
                            srng,
                            obs_t,
                            act,
                            &mut pbe.logp[t * b..(t + 1) * b],
                            &mut pbe.val[t * b..(t + 1) * b],
                        );
                        pbe.act[t * b * p..(t + 1) * b * p].copy_from_slice(act);
                    });
                }
                FleetBenchPolicy::FusedNet | FleetBenchPolicy::CoupledNet => {
                    let mut pols: Vec<PolicyRollout<'_>> = pb
                        .iter_mut()
                        .map(|p| PolicyRollout {
                            actions: &mut p.act,
                            logp: &mut p.logp,
                            values: &mut p.val,
                        })
                        .collect();
                    fleet.rollout_fused(
                        t_chunk, &mut bufs, &mut pols, &learners, chunk as u64, false,
                    );
                }
                FleetBenchPolicy::GeneralistNet => {
                    let g = gen.as_ref().expect("generalist net built for this policy");
                    let mut pols: Vec<PolicyRollout<'_>> = pb
                        .iter_mut()
                        .map(|p| PolicyRollout {
                            actions: &mut p.act,
                            logp: &mut p.logp,
                            values: &mut p.val,
                        })
                        .collect();
                    let views: Vec<PolicyRef<'_>> =
                        (0..n).map(|e| PolicyRef::Generalist(g, e)).collect();
                    fleet.rollout_fused_with(
                        t_chunk, &mut bufs, &mut pols, &views, chunk as u64, false,
                    );
                }
            }
        }
    };
    pass(&mut fleet, &mut eb, &mut pb); // warm (also builds the pool)
    let t0 = Instant::now();
    pass(&mut fleet, &mut eb, &mut pb);
    let el = t0.elapsed().as_secs_f64();
    let steps = (n_chunks * t_chunk * total_lanes) as f64;
    Ok((steps / el, el * 100_000.0 / steps, total_lanes, n))
}

/// Measure end-to-end fleet TRAINING throughput (fused rollout + sharded
/// PPO update per iteration) with the pipeline either barriered
/// (`overlap == false`) or double-buffered (`overlap == true`) — the
/// `pipeline-overlapped` bench rows pair the two at matched lanes so the
/// table isolates what the overlap window buys. One warm barrier
/// iteration builds the pool, then `iters` timed iterations run with the
/// requested mode (the last via [`FleetPpoTrainer::final_iteration`], so
/// both modes execute exactly `iters` rollouts + `iters` updates inside
/// the timed region). Returns `(env-steps/sec, seconds per 100k env
/// steps, total lanes, families)`.
pub fn measure_fleet_training_throughput(
    spec: &FleetSpec,
    store: Option<&DataStore>,
    threads: usize,
    iters: usize,
    overlap: bool,
) -> Result<(f64, f64, usize, usize)> {
    let mut fleet = Fleet::from_spec(spec, store)?;
    fleet.set_threads(threads);
    let total_lanes = fleet.total_lanes();
    let n = fleet.n_envs();
    let hp = PpoParams {
        rollout_steps: 64,
        n_minibatches: 4,
        update_epochs: 2,
        hidden: BENCH_POLICY_HIDDEN,
        threads,
        overlap,
        ..Default::default()
    };
    let t_len = hp.rollout_steps;
    let mut tr = FleetPpoTrainer::new(hp, fleet, 9);
    // Warm without a trailing prefetch so no pending rollout crosses the
    // timing boundary in either mode.
    tr.final_iteration();
    let iters = iters.max(1);
    let t0 = Instant::now();
    for i in 0..iters {
        if i + 1 == iters {
            tr.final_iteration();
        } else {
            tr.iteration();
        }
    }
    let el = t0.elapsed().as_secs_f64();
    let steps = (iters * t_len * total_lanes) as f64;
    Ok((steps / el, el * 100_000.0 / steps, total_lanes, n))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fused fleet PPO iteration over the demo spec runs end-to-end,
    /// returns finite per-family stats, and accounts env steps.
    #[test]
    fn fleet_ppo_iteration_trains_all_families() {
        let fleet = Fleet::from_spec(&FleetSpec::demo(9, 1), None).unwrap();
        let lanes = fleet.total_lanes();
        let hp = PpoParams {
            rollout_steps: 24,
            n_minibatches: 2,
            update_epochs: 2,
            hidden: 32,
            ..Default::default()
        };
        let mut tr = FleetPpoTrainer::new(hp, fleet, 5);
        let stats = tr.iteration();
        assert_eq!(stats.len(), 3);
        for s in &stats {
            assert!(s.mean_reward.is_finite(), "{}: reward", s.label);
            assert!(s.total_loss.is_finite(), "{}: loss", s.label);
            assert!(s.entropy > 0.0, "{}: entropy", s.label);
        }
        assert_eq!(tr.env_steps, lanes * 24);
        // Greedy eval runs on every family and every scenario cell,
        // including V2G and battery-less configs, and names each cell.
        for e in 0..tr.fleet.n_envs() {
            let evals = tr.eval_cells(e, 123);
            assert_eq!(evals.len(), tr.fleet.env(e).n_scenarios());
            let lane_sum: usize = evals.iter().map(|c| c.lanes).sum();
            assert_eq!(lane_sum, tr.fleet.env(e).batch(), "cell lane counts must cover the batch");
            for c in &evals {
                assert!(c.reward.is_finite() && c.profit.is_finite(), "{}/{}", c.family, c.cell);
                assert!(!c.cell.is_empty());
                assert!(c.lanes > 0, "{}: cell {} has no training lanes", c.family, c.cell);
            }
        }
        // The demo's first family trains on a 4-cell grid — per-cell eval
        // must surface all of them, not just lane 0's.
        assert!(tr.fleet.env(0).n_scenarios() > 1);
        assert_eq!(tr.eval_all_cells(7).len(),
            (0..tr.fleet.n_envs()).map(|e| tr.fleet.env(e).n_scenarios()).sum::<usize>());
    }

    #[test]
    fn fleet_throughput_probe_runs() {
        for policy in [
            FleetBenchPolicy::Random,
            FleetBenchPolicy::SerialNet,
            FleetBenchPolicy::FusedNet,
            FleetBenchPolicy::GeneralistNet,
        ] {
            let (sps, s100k, lanes, fams) =
                measure_fleet_throughput(&FleetSpec::demo(2, 1), None, 2, 2_000, policy).unwrap();
            assert!(sps > 0.0 && s100k > 0.0, "{}", policy.label());
            assert_eq!(lanes, 20);
            assert_eq!(fams, 3);
        }
        // The coupled row runs the propose → allocate → commit double
        // dispatch over the feeder-coupled demo (same lane grid).
        let (sps, s100k, lanes, fams) = measure_fleet_throughput(
            &FleetSpec::demo_coupled(2, 1),
            None,
            2,
            2_000,
            FleetBenchPolicy::CoupledNet,
        )
        .unwrap();
        assert!(sps > 0.0 && s100k > 0.0, "fleet-coupled");
        assert_eq!(lanes, 20);
        assert_eq!(fams, 3);
    }

    /// The generalist path: one shared-trunk policy trains across all
    /// three heterogeneous demo families in a single fused dispatch per
    /// step, and a holdout cell shows up in eval as a zero-shot row
    /// (lanes == 0) while never entering training.
    #[test]
    fn generalist_iteration_trains_and_reports_holdout() {
        let mut spec = FleetSpec::demo(9, 1);
        spec.holdout = vec!["shopping/NL/2022/high".into()];
        let fleet = Fleet::from_spec(&spec, None).unwrap();
        let lanes = fleet.total_lanes();
        let hp = PpoParams {
            rollout_steps: 24,
            n_minibatches: 2,
            update_epochs: 2,
            hidden: 32,
            ..Default::default()
        };
        let mut tr = FleetPpoTrainer::new_generalist(hp, fleet, 5);
        assert_eq!(tr.policy.label(), "generalist");
        let before = tr.policy.params_flat();
        let stats = tr.iteration();
        assert_eq!(stats.len(), 3);
        for s in &stats {
            assert!(s.mean_reward.is_finite(), "{}: reward", s.label);
            assert!(s.total_loss.is_finite(), "{}: loss", s.label);
            assert!(s.entropy > 0.0, "{}: entropy", s.label);
        }
        assert_eq!(tr.env_steps, lanes * 24);
        let after = tr.policy.params_flat();
        assert_eq!(before.len(), after.len());
        assert!(
            before.iter().zip(&after).any(|(a, b)| a != b),
            "update did not move the generalist's weights"
        );
        // Eval: the held-out cell reports zero-shot (family 0 holds it).
        let evals = tr.eval_all_cells(123);
        let held: Vec<_> = evals.iter().filter(|c| c.holdout).collect();
        assert_eq!(held.len(), 1);
        assert_eq!(held[0].cell, "shopping/NL/2022/high");
        assert_eq!(held[0].lanes, 0);
        assert!(held[0].reward.is_finite() && held[0].profit.is_finite());
        assert_eq!(held[0].episodes, 1, "one full greedy episode per cell");
        for c in evals.iter().filter(|c| !c.holdout) {
            assert!(c.lanes > 0, "{}: trained cell {} has no lanes", c.family, c.cell);
            assert_ne!(c.cell, "shopping/NL/2022/high", "holdout leaked into training cells");
            assert_eq!(c.episodes, 1);
        }
    }
}
