//! Fused fleet rollout + per-family PPO.
//!
//! [`Fleet::rollout`] is the cross-env analogue of
//! [`VectorEnv::rollout`]: per step it asks the caller's policy for each
//! family's action row, splits **every** family's lanes into shard tasks
//! (shard → (env, lane-range) map from [`Fleet::plan_shards`]), and
//! dispatches all of them in one worker-pool call — heterogeneous
//! stations advance concurrently instead of one pool per env in series.
//! Each shard observes its own lanes right after stepping them, writing
//! straight into that family's [`RolloutBuffers`].
//!
//! [`FleetPpoTrainer`] puts a [`Learner`] (policy + value + Adam) on each
//! family and trains all of them from a single fused rollout per
//! iteration.

use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::baselines::ppo::{Learner, PpoParams};
use crate::data::DataStore;
use crate::env::core::{StepInfo, STEPS_PER_EPISODE};
use crate::env::scalar::ScalarEnv;
use crate::env::vector::{RolloutBuffers, ShardTask, StepOut};
use crate::runtime::pool::WorkerPool;
use crate::util::rng::Rng;

use super::{Fleet, FleetSpec};

impl Fleet {
    /// Advance every family `n_steps` times in lockstep, writing each
    /// family's observations/rewards/dones/profits into its own
    /// [`RolloutBuffers`] (`bufs[e]`, laid out exactly as
    /// [`VectorEnv::rollout`] expects:
    /// obs `[(T+1) * B_e * obs_dim_e]`, the rest `[T * B_e]`).
    ///
    /// `policy(env, t, obs_t, actions)` reads family `env`'s
    /// `[B_e * obs_dim_e]` observation row for step `t` and fills its
    /// `[B_e * n_ports_e]` action row; policies run on the caller thread,
    /// stepping+observing runs sharded across the fleet-wide pool.
    ///
    /// Bit-identical to rolling the member envs out independently, for
    /// any thread count (lane RNG is counter-based; shard placement never
    /// changes what a lane computes).
    pub fn rollout<F>(&mut self, n_steps: usize, bufs: &mut [RolloutBuffers<'_>], mut policy: F)
    where
        F: FnMut(usize, usize, &[f32], &mut [usize]),
    {
        let n = self.n_envs();
        assert_eq!(bufs.len(), n, "need one RolloutBuffers per fleet env");
        let dims: Vec<(usize, usize, usize)> = (0..n)
            .map(|e| {
                let env = self.env(e);
                (env.batch(), env.n_ports(), env.obs_dim())
            })
            .collect();
        for (e, (&(b, _, d), buf)) in dims.iter().zip(bufs.iter()).enumerate() {
            assert_eq!(buf.obs.len(), (n_steps + 1) * b * d, "env {e}: obs must be [(T+1)*B*obs_dim]");
            assert_eq!(buf.rewards.len(), n_steps * b, "env {e}: rewards must be [T*B]");
            assert_eq!(buf.dones.len(), n_steps * b, "env {e}: dones must be [T*B]");
            assert_eq!(buf.profits.len(), n_steps * b, "env {e}: profits must be [T*B]");
        }
        let plan = self.plan_shards();
        let total: usize = plan.iter().sum();
        // `--threads` is a hard concurrency cap: the pool is sized to it,
        // and when the fleet has more shard tasks than pool lanes the
        // dispatcher strides tasks over the lanes instead of widening the
        // pool. `threads == 1` (or a single task) runs fully inline — no
        // worker threads at all.
        let width = total.min(self.threads.max(1));
        let pool = if width > 1 { Some(self.ensure_pool(width)) } else { None };

        let mut actions: Vec<Vec<usize>> =
            dims.iter().map(|&(b, p, _)| vec![0usize; b * p]).collect();
        let mut infos: Vec<Vec<StepInfo>> =
            dims.iter().map(|&(b, _, _)| vec![StepInfo::default(); b]).collect();

        for ((env, buf), &(b, _, d)) in self.envs.iter().zip(bufs.iter_mut()).zip(&dims) {
            env.observe_all(&mut buf.obs[..b * d]);
        }
        for t in 0..n_steps {
            // Policies first (serial, caller thread), then one pooled
            // dispatch covering every family's shard tasks.
            let mut tasks = Vec::with_capacity(total);
            for ((((env_idx, env), buf), act), info) in self
                .envs
                .iter_mut()
                .enumerate()
                .zip(bufs.iter_mut())
                .zip(actions.iter_mut())
                .zip(infos.iter_mut())
            {
                let (b, _, d) = dims[env_idx];
                let (obs_t, obs_rest) = buf.obs[t * b * d..].split_at_mut(b * d);
                policy(env_idx, t, obs_t, act);
                let out = StepOut {
                    obs: &mut obs_rest[..b * d],
                    rewards: &mut buf.rewards[t * b..(t + 1) * b],
                    dones: &mut buf.dones[t * b..(t + 1) * b],
                    profits: &mut buf.profits[t * b..(t + 1) * b],
                };
                tasks.extend(env.shard_tasks(act, info, Some(out), plan[env_idx]));
            }
            run_fleet_tasks(pool.as_deref(), &mut tasks);
        }
    }
}

/// Dispatch one step's shard tasks (from all families) over at most
/// `pool.max_shards()` concurrent lanes: pool lane `s` runs tasks
/// `s, s + width, s + 2·width, ...` serially. This is what lets the fleet
/// honor a `--threads` cap smaller than its task count — the per-env
/// runtime never queues more shards than threads, so it has no such path.
/// Without a pool (or with a single task) everything runs inline on the
/// caller thread. Task-to-lane placement never changes what a task
/// computes, so results are identical for any width.
fn run_fleet_tasks(pool: Option<&WorkerPool>, tasks: &mut [ShardTask<'_>]) {
    match pool {
        Some(pool) if tasks.len() > 1 && pool.max_shards() > 1 => {
            let width = pool.max_shards().min(tasks.len());
            let wrapped: Vec<Mutex<&mut ShardTask<'_>>> =
                tasks.iter_mut().map(Mutex::new).collect();
            pool.run(width, |s| {
                let mut k = s;
                while k < wrapped.len() {
                    wrapped[k].lock().unwrap().run();
                    k += width;
                }
            });
        }
        _ => {
            for task in tasks {
                task.run();
            }
        }
    }
}

/// Per-family rollout storage for one PPO iteration (env-written half).
struct EnvBufs {
    obs: Vec<f32>,
    rew: Vec<f32>,
    done: Vec<f32>,
    profit: Vec<f32>,
}

impl EnvBufs {
    fn new(b: usize, d: usize, t_len: usize) -> EnvBufs {
        EnvBufs {
            obs: vec![0.0; (t_len + 1) * b * d],
            rew: vec![0.0; t_len * b],
            done: vec![0.0; t_len * b],
            profit: vec![0.0; t_len * b],
        }
    }

    fn as_rollout_buffers(&mut self) -> RolloutBuffers<'_> {
        RolloutBuffers {
            obs: &mut self.obs,
            rewards: &mut self.rew,
            dones: &mut self.done,
            profits: &mut self.profit,
        }
    }
}

/// Per-iteration training stats for one station family.
pub struct FamilyStats {
    pub label: String,
    pub lanes: usize,
    pub mean_reward: f32,
    pub mean_profit: f32,
    pub total_loss: f32,
    pub entropy: f32,
    pub completed_return_mean: f32,
}

/// PPO over a fleet: one [`Learner`] per station family (families have
/// different obs/action dims, so weights cannot be shared), all families
/// rolled out in one fused [`Fleet::rollout`] pass per iteration.
pub struct FleetPpoTrainer {
    pub hp: PpoParams,
    pub fleet: Fleet,
    pub learners: Vec<Learner>,
    pub rng: Rng,
    pub env_steps: usize,
    /// Per-family, per-lane running episode returns (same accounting as
    /// `PpoTrainer`).
    running_return: Vec<Vec<f32>>,
}

impl FleetPpoTrainer {
    /// `hp.num_envs` is ignored — the fleet's lane counts come from its
    /// spec; everything else (lr, rollout length, epochs, ...) is shared
    /// across families.
    pub fn new(hp: PpoParams, fleet: Fleet, seed: u64) -> FleetPpoTrainer {
        let mut rng = Rng::new(seed);
        let learners: Vec<Learner> = (0..fleet.n_envs())
            .map(|e| {
                let env = fleet.env(e);
                Learner::new(&mut rng, env.obs_dim(), hp.hidden, env.action_nvec())
            })
            .collect();
        let running_return =
            (0..fleet.n_envs()).map(|e| vec![0.0; fleet.env(e).batch()]).collect();
        FleetPpoTrainer { hp, fleet, learners, rng, env_steps: 0, running_return }
    }

    /// Env steps consumed by one `iteration` (all families).
    pub fn steps_per_iteration(&self) -> usize {
        self.fleet.total_lanes() * self.hp.rollout_steps
    }

    /// One fused rollout + one PPO update per family.
    pub fn iteration(&mut self) -> Vec<FamilyStats> {
        let t_len = self.hp.rollout_steps;
        let n = self.fleet.n_envs();
        let dims: Vec<(usize, usize, usize)> = (0..n)
            .map(|e| {
                let env = self.fleet.env(e);
                (env.batch(), env.n_ports(), env.obs_dim())
            })
            .collect();
        let mut eb: Vec<EnvBufs> =
            dims.iter().map(|&(b, _, d)| EnvBufs::new(b, d, t_len)).collect();
        struct PolBufs {
            act: Vec<usize>,
            logp: Vec<f32>,
            val: Vec<f32>,
        }
        let mut pb: Vec<PolBufs> = dims
            .iter()
            .map(|&(b, p, _)| PolBufs {
                act: vec![0usize; t_len * b * p],
                logp: vec![0.0; t_len * b],
                val: vec![0.0; t_len * b],
            })
            .collect();

        {
            let FleetPpoTrainer { fleet, learners, rng, .. } = self;
            let mut bufs: Vec<RolloutBuffers<'_>> =
                eb.iter_mut().map(EnvBufs::as_rollout_buffers).collect();
            fleet.rollout(t_len, &mut bufs, |e, t, obs_t, actions| {
                let (b, p, _) = dims[e];
                let pbe = &mut pb[e];
                learners[e].sample_row(
                    rng,
                    obs_t,
                    actions,
                    &mut pbe.logp[t * b..(t + 1) * b],
                    &mut pbe.val[t * b..(t + 1) * b],
                );
                pbe.act[t * b * p..(t + 1) * b * p].copy_from_slice(actions);
            });
        }
        self.env_steps += self.fleet.total_lanes() * t_len;

        let mut out = Vec::with_capacity(n);
        for e in 0..n {
            let (b, _, _) = dims[e];
            let bsz = b * t_len;
            let mut profit_sum = 0f64;
            let mut comp: Vec<f32> = Vec::new();
            for t in 0..t_len {
                for j in 0..b {
                    let idx = t * b + j;
                    profit_sum += eb[e].profit[idx] as f64;
                    self.running_return[e][j] += eb[e].rew[idx];
                    if eb[e].done[idx] > 0.5 {
                        comp.push(self.running_return[e][j]);
                        self.running_return[e][j] = 0.0;
                    }
                }
            }
            let (total_loss, entropy) = self.learners[e].update(
                &self.hp,
                &mut self.rng,
                b,
                t_len,
                &eb[e].obs,
                &pb[e].act,
                &pb[e].logp,
                &pb[e].val,
                &eb[e].rew,
                &eb[e].done,
            );
            out.push(FamilyStats {
                label: self.fleet.label(e).to_string(),
                lanes: b,
                mean_reward: eb[e].rew.iter().sum::<f32>() / bsz as f32,
                mean_profit: (profit_sum / bsz as f64) as f32,
                total_loss,
                entropy,
                completed_return_mean: if comp.is_empty() {
                    0.0
                } else {
                    comp.iter().sum::<f32>() / comp.len() as f32
                },
            });
        }
        out
    }

    /// Greedy single-episode eval for family `e`: fresh B=1 scalar env on
    /// that family's config and lane-0 scenario tables (Arc-shared).
    pub fn eval_episode(&self, e: usize, seed: u64) -> (f32, f32) {
        let fam = self.fleet.env(e);
        let mut env = ScalarEnv::new(fam.cfg.clone(), fam.tables_arc(0), seed);
        let mut obs = vec![0f32; self.learners[e].obs_dim];
        let mut action = vec![0usize; self.learners[e].n_ports()];
        let mut tot_r = 0f32;
        let mut tot_p = 0f32;
        for _ in 0..STEPS_PER_EPISODE {
            env.observe(&mut obs);
            self.learners[e].greedy_action(&obs, &mut action);
            let info = env.step(&action);
            tot_r += info.reward;
            tot_p += info.profit;
        }
        (tot_r, tot_p)
    }
}

/// Measure fused fleet-rollout throughput with random actions: one warm
/// pass then one timed pass over pre-drawn action chunks (same protocol
/// as [`crate::env::vector::measure_throughput`], so fleet rows in
/// BENCH_fleet.json are comparable to the single-env sweep). Returns
/// `(env-steps/sec, seconds per 100k env steps, total lanes, families)`.
pub fn measure_fleet_throughput(
    spec: &FleetSpec,
    store: Option<&DataStore>,
    threads: usize,
    budget: usize,
) -> Result<(f64, f64, usize, usize)> {
    let mut fleet = Fleet::from_spec(spec, store)?;
    fleet.set_threads(threads);
    let n = fleet.n_envs();
    let total_lanes = fleet.total_lanes();
    let t_chunk = 64usize;
    let n_chunks = (budget / (total_lanes * t_chunk).max(1)).clamp(1, 300);
    let dims: Vec<(usize, usize, usize)> = (0..n)
        .map(|e| {
            let env = fleet.env(e);
            (env.batch(), env.n_ports(), env.obs_dim())
        })
        .collect();
    let mut arng = Rng::new(23);
    let actions: Vec<Vec<usize>> = (0..n)
        .map(|e| {
            let (b, p, _) = dims[e];
            let nvec = fleet.env(e).action_nvec();
            (0..t_chunk * b * p)
                .map(|k| arng.below(nvec[k % p] as u32) as usize)
                .collect()
        })
        .collect();
    let mut eb: Vec<EnvBufs> =
        dims.iter().map(|&(b, _, d)| EnvBufs::new(b, d, t_chunk)).collect();
    let mut pass = |fleet: &mut Fleet, eb: &mut [EnvBufs]| {
        for _ in 0..n_chunks {
            let mut bufs: Vec<RolloutBuffers<'_>> =
                eb.iter_mut().map(EnvBufs::as_rollout_buffers).collect();
            fleet.rollout(t_chunk, &mut bufs, |e, t, _obs, act| {
                let (b, p, _) = dims[e];
                act.copy_from_slice(&actions[e][t * b * p..(t + 1) * b * p]);
            });
        }
    };
    pass(&mut fleet, &mut eb); // warm (also builds the pool)
    let t0 = Instant::now();
    pass(&mut fleet, &mut eb);
    let el = t0.elapsed().as_secs_f64();
    let steps = (n_chunks * t_chunk * total_lanes) as f64;
    Ok((steps / el, el * 100_000.0 / steps, total_lanes, n))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fused fleet PPO iteration over the demo spec runs end-to-end,
    /// returns finite per-family stats, and accounts env steps.
    #[test]
    fn fleet_ppo_iteration_trains_all_families() {
        let fleet = Fleet::from_spec(&FleetSpec::demo(9, 1), None).unwrap();
        let lanes = fleet.total_lanes();
        let hp = PpoParams {
            rollout_steps: 24,
            n_minibatches: 2,
            update_epochs: 2,
            hidden: 32,
            ..Default::default()
        };
        let mut tr = FleetPpoTrainer::new(hp, fleet, 5);
        let stats = tr.iteration();
        assert_eq!(stats.len(), 3);
        for s in &stats {
            assert!(s.mean_reward.is_finite(), "{}: reward", s.label);
            assert!(s.total_loss.is_finite(), "{}: loss", s.label);
            assert!(s.entropy > 0.0, "{}: entropy", s.label);
        }
        assert_eq!(tr.env_steps, lanes * 24);
        // Greedy eval runs on every family, including V2G and
        // battery-less configs.
        for e in 0..tr.fleet.n_envs() {
            let (r, p) = tr.eval_episode(e, 123);
            assert!(r.is_finite() && p.is_finite());
        }
    }

    #[test]
    fn fleet_throughput_probe_runs() {
        let (sps, s100k, lanes, fams) =
            measure_fleet_throughput(&FleetSpec::demo(2, 1), None, 2, 2_000).unwrap();
        assert!(sps > 0.0 && s100k > 0.0);
        assert_eq!(lanes, 20);
        assert_eq!(fams, 3);
    }
}
