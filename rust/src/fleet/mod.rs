//! Scenario fleet subsystem: heterogeneous multi-station scheduling on
//! one worker pool.
//!
//! The paper's modularity claim is that one simulator covers diverse
//! real-world station configurations; the runtime below makes that true
//! at training time. A [`Fleet`] owns N [`VectorEnv`]s with *different*
//! `StationConfig`s — different charger mixes, battery options, V2G
//! capability, hence different obs/action dimensions — and drives all of
//! them concurrently on a **single** persistent
//! [`WorkerPool`](crate::runtime::pool::WorkerPool) via a
//! shard → (env, lane-range) map. One fused [`Fleet::rollout`] call (see
//! [`rollout`]) advances every family and writes each family's
//! observations/rewards/dones/profits into its own PPO buffers, so a
//! policy per station family trains in one pass instead of serializing
//! one pool per env.
//!
//! * [`catalog`] — the declarative `ScenarioSpec` grid (country ×
//!   price-year × traffic × user-profile × layout × v2g), seeded
//!   expansion, and the `Arc<ScenarioTables>` dedup cache.
//! * [`rollout`] — the fused cross-env rollout and the per-family PPO
//!   trainer ([`rollout::FleetPpoTrainer`]).
//!
//! Determinism: every lane's `CounterRng` stream depends only on its seed
//! and draw count, and shard tasks compute the same result wherever they
//! run — so a fleet rollout is bit-identical to rolling the member envs
//! out independently, for any thread count (proven in
//! rust/tests/fleet.rs).

pub mod catalog;
pub mod grid;
pub mod rollout;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::data::DataStore;
use crate::env::core::ScenarioTables;
use crate::env::vector::{VectorEnv, MIN_LANES_PER_SHARD, PAR_MIN_BATCH};
use crate::runtime::pool::WorkerPool;

pub use catalog::{
    expand, FleetSpec, GridShape, HeadSpec, ScenarioSpec, StationLayout, TableCache,
};
pub use grid::{CurtailPolicy, GridSpec};
pub use rollout::{
    family_policy_seed, measure_fleet_throughput, measure_fleet_training_throughput, CellEval,
    FamilyStats, FleetBenchPolicy, FleetPolicy, FleetPpoTrainer,
};

/// N heterogeneous station environments scheduled on one worker pool.
pub struct Fleet {
    envs: Vec<VectorEnv>,
    labels: Vec<String>,
    /// Per-env scenario-cell names, indexed like each env's table set
    /// (`cell_labels[e][cell]`); used by per-cell eval reporting.
    cell_labels: Vec<Vec<String>>,
    /// Shard-count ceiling across the whole fleet (`--threads`; 0 = auto).
    threads: usize,
    /// One pool for every env; rebuilt lazily when the plan outgrows it.
    pool: Option<Arc<WorkerPool>>,
    /// Separate pool for the sharded PPO update when its chunk demand
    /// exceeds the rollout pool's width (see `VectorEnv::shared_pool` for
    /// why the rollout pool must not be grown past its shard demand).
    aux_pool: Option<Arc<WorkerPool>>,
    /// Per-env held-out scenario cells (`holdout` schema key): name +
    /// tables pairs, excluded from every training lane, evaluated
    /// zero-shot by per-cell eval. Empty for hand-built fleets and specs
    /// without a `holdout` key.
    holdout: Vec<Vec<(String, Arc<ScenarioTables>)>>,
    /// Per-env feeder coupling (`grid` schema key, normalized): `Some`
    /// exactly for families whose `cfg.grid_coupled` is set. Families
    /// sharing a feeder name form one coupling group — see
    /// [`Fleet::coupling_groups`]. Always `None` for hand-built fleets.
    grids: Vec<Option<GridSpec>>,
}

impl Fleet {
    /// Assemble a fleet from already-built envs (tests and power users);
    /// most callers go through [`Fleet::from_spec`]. Scenario cells get
    /// generic `cell{i}` names (the catalog path names them properly).
    pub fn from_envs(envs: Vec<VectorEnv>, labels: Vec<String>) -> Result<Fleet> {
        let cell_labels = envs
            .iter()
            .map(|e| (0..e.n_scenarios()).map(|i| format!("cell{i}")).collect())
            .collect();
        Fleet::from_envs_with_cells(envs, labels, cell_labels)
    }

    fn from_envs_with_cells(
        envs: Vec<VectorEnv>,
        labels: Vec<String>,
        cell_labels: Vec<Vec<String>>,
    ) -> Result<Fleet> {
        if envs.is_empty() {
            bail!("a fleet needs at least one environment");
        }
        if envs.len() != labels.len() {
            bail!("{} envs but {} labels", envs.len(), labels.len());
        }
        if envs.len() != cell_labels.len() {
            bail!("{} envs but {} cell-label sets", envs.len(), cell_labels.len());
        }
        for (e, (env, cells)) in envs.iter().zip(&cell_labels).enumerate() {
            if env.n_scenarios() != cells.len() {
                bail!(
                    "env {e}: {} scenario cells but {} cell labels",
                    env.n_scenarios(),
                    cells.len()
                );
            }
        }
        let holdout = vec![Vec::new(); envs.len()];
        let grids = vec![None; envs.len()];
        Ok(Fleet {
            envs,
            labels,
            cell_labels,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            pool: None,
            aux_pool: None,
            holdout,
            grids,
        })
    }

    /// Expand a [`FleetSpec`] (catalog grid) and build one `VectorEnv` per
    /// station family. `store` is the artifact data stack; `None` falls
    /// back to synthetic per-scenario tables.
    pub fn from_spec(spec: &FleetSpec, store: Option<&DataStore>) -> Result<Fleet> {
        let families = catalog::expand(spec, store)?;
        let mut envs = Vec::with_capacity(families.len());
        let mut labels = Vec::with_capacity(families.len());
        let mut cell_labels = Vec::with_capacity(families.len());
        let mut holdout = Vec::with_capacity(families.len());
        let mut grids = Vec::with_capacity(families.len());
        for fam in families {
            envs.push(VectorEnv::with_seeds(
                fam.cfg,
                fam.tables,
                fam.lane_scenario,
                &fam.seeds,
            ));
            labels.push(fam.label);
            cell_labels.push(fam.cell_names);
            holdout.push(
                fam.holdout_names.into_iter().zip(fam.holdout_tables).collect(),
            );
            grids.push(fam.grid);
        }
        let mut fleet = Fleet::from_envs_with_cells(envs, labels, cell_labels)?;
        fleet.holdout = holdout;
        fleet.set_grids(grids)?;
        Ok(fleet)
    }

    /// Install per-family feeder couplings, validating the coupling
    /// invariant the rollout's allocate phase depends on: every `Some`
    /// entry must carry a concrete, finite, positive `capacity_kw`
    /// (doc-only `capacity_kw: null` specs normalize to `None` at catalog
    /// expansion and must arrive here as `None`), and every family on one
    /// feeder must agree on its definition. Violations return a named
    /// error — feeder name + family index/label — instead of the old
    /// rollout-time `expect` panic deep inside the allocate phase.
    pub fn set_grids(&mut self, grids: Vec<Option<GridSpec>>) -> Result<()> {
        if grids.len() != self.envs.len() {
            bail!("{} envs but {} grid entries", self.envs.len(), grids.len());
        }
        let mut feeders: Vec<(&GridSpec, usize)> = Vec::new();
        for (e, g) in grids.iter().enumerate() {
            let Some(g) = g else { continue };
            match g.capacity_kw {
                None => bail!(
                    "feeder \"{}\" (family {e} '{}'): capacity_kw is null — a \
                     doc-only grid entry must not couple; pass None instead",
                    g.feeder,
                    self.labels[e],
                ),
                Some(cap) if !cap.is_finite() || cap <= 0.0 => bail!(
                    "feeder \"{}\" (family {e} '{}'): capacity_kw ({cap}) must be \
                     finite and > 0",
                    g.feeder,
                    self.labels[e],
                ),
                Some(_) => {}
            }
            match feeders.iter().find(|(spec, _)| spec.feeder == g.feeder) {
                Some((spec, first)) if *spec != g => bail!(
                    "families {first} and {e} ('{}') both name feeder \"{}\" but \
                     with different capacity_kw/policy — one feeder, one definition",
                    self.labels[e],
                    g.feeder,
                ),
                Some(_) => {}
                None => feeders.push((g, e)),
            }
        }
        self.grids = grids;
        Ok(())
    }

    pub fn n_envs(&self) -> usize {
        self.envs.len()
    }

    pub fn env(&self, i: usize) -> &VectorEnv {
        &self.envs[i]
    }

    pub fn label(&self, i: usize) -> &str {
        &self.labels[i]
    }

    /// Name of scenario cell `cell` of family `e` (e.g.
    /// `shopping/NL/2021/medium`, or `cell0` for hand-built fleets).
    pub fn cell_label(&self, e: usize, cell: usize) -> &str {
        &self.cell_labels[e][cell]
    }

    /// Held-out scenario cells of family `e` (name, tables) — cells the
    /// `holdout` schema key carved out of training, kept for zero-shot
    /// per-cell eval.
    pub fn holdout_cells(&self, e: usize) -> &[(String, Arc<ScenarioTables>)] {
        &self.holdout[e]
    }

    /// Feeder coupling of family `e` (`None` for uncoupled families and
    /// every hand-built fleet).
    pub fn grid(&self, e: usize) -> Option<&GridSpec> {
        self.grids[e].as_ref()
    }

    /// Whether any family is feeder-coupled — i.e. whether the rollout
    /// must run the two-phase propose → allocate → commit step at all.
    pub fn has_coupling(&self) -> bool {
        self.grids.iter().any(Option::is_some)
    }

    /// Coupling groups in deterministic first-appearance env order: one
    /// `(spec, member env indices)` entry per distinct feeder name.
    /// Catalog expansion already guarantees one definition per feeder, so
    /// the first spec seen for a name is THE spec.
    pub fn coupling_groups(&self) -> Vec<(GridSpec, Vec<usize>)> {
        let mut groups: Vec<(GridSpec, Vec<usize>)> = Vec::new();
        for (e, g) in self.grids.iter().enumerate() {
            let Some(g) = g else { continue };
            match groups.iter_mut().find(|(spec, _)| spec.feeder == g.feeder) {
                Some((_, members)) => members.push(e),
                None => groups.push((g.clone(), vec![e])),
            }
        }
        groups
    }

    /// Policy input/output shape of the whole fleet: padded obs width plus
    /// one head spec per family, in env order (the generalist's
    /// constructor spec).
    pub fn grid_shape(&self) -> GridShape {
        let heads: Vec<HeadSpec> = self
            .envs
            .iter()
            .zip(&self.labels)
            .map(|(env, label)| HeadSpec {
                label: label.clone(),
                obs_dim: env.obs_dim(),
                action_nvec: env.action_nvec(),
            })
            .collect();
        let pad_obs = heads.iter().map(|h| h.obs_dim).max().unwrap_or(0);
        GridShape { pad_obs, heads }
    }

    pub fn total_lanes(&self) -> usize {
        self.envs.iter().map(|e| e.batch()).sum()
    }

    /// Cap the fleet-wide shard/worker budget (`--threads`). `0` restores
    /// the `available_parallelism()` default. Rebuilds the pool lazily.
    pub fn set_threads(&mut self, threads: usize) {
        let t = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        if t != self.threads {
            self.threads = t;
            self.pool = None;
            self.aux_pool = None;
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The shard → (env, lane-range) map for the current lane counts:
    /// `plan[e]` shards for env `e`, each covering a contiguous lane block
    /// (the per-env split is [`VectorEnv::shard_tasks`]' — boundaries
    /// depend only on `(B_e, plan[e])`). The thread budget is split
    /// proportionally to lane counts; every env gets at least one shard,
    /// and envs below the sharding thresholds stay single-shard so tiny
    /// families don't pay wakeup overhead. The plan's *total* may exceed
    /// `threads` when there are more families than threads — concurrency
    /// is still capped at dispatch time (`rollout::run_fleet_tasks`
    /// strides tasks over at most `threads` pool lanes).
    pub(crate) fn plan_shards(&self) -> Vec<usize> {
        let lanes: Vec<usize> = self.envs.iter().map(|e| e.batch()).collect();
        let total: usize = lanes.iter().sum::<usize>().max(1);
        let budget = self.threads.max(1);
        lanes
            .iter()
            .map(|&b| {
                let cap = if b >= PAR_MIN_BATCH {
                    (b / MIN_LANES_PER_SHARD).max(1)
                } else {
                    1
                };
                (budget * b / total).clamp(1, cap)
            })
            .collect()
    }

    /// The fleet-wide pool, grown (rebuilt) if `shards` outruns it.
    pub(crate) fn ensure_pool(&mut self, shards: usize) -> Arc<WorkerPool> {
        let need = shards.max(1);
        let rebuild = match &self.pool {
            Some(p) => p.max_shards() < need,
            None => true,
        };
        if rebuild {
            self.pool = Some(Arc::new(WorkerPool::new(need)));
        }
        Arc::clone(self.pool.as_ref().expect("pool just built"))
    }

    /// A pool with at least `width` lanes for the pooled PPO update:
    /// reuses the rollout pool when it is already wide enough, otherwise
    /// grows the auxiliary pool — never the rollout pool (its width sets
    /// how many workers every per-step dispatch wakes).
    pub(crate) fn update_pool(&mut self, width: usize) -> Option<Arc<WorkerPool>> {
        crate::runtime::pool::aux_or_primary_pool(
            &self.pool,
            &mut self.aux_pool,
            self.threads,
            width,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::core::ScenarioTables;
    use crate::env::tree::StationConfig;

    fn tiny_env(b: usize, seed: u64) -> VectorEnv {
        VectorEnv::new(
            StationConfig::default(),
            ScenarioTables::synthetic(1.0),
            b,
            seed,
        )
    }

    #[test]
    fn shard_plan_is_proportional_with_floors() {
        let mut fleet = Fleet::from_envs(
            vec![tiny_env(256, 1), tiny_env(64, 2), tiny_env(4, 3)],
            vec!["a".into(), "b".into(), "c".into()],
        )
        .unwrap();
        fleet.set_threads(8);
        let plan = fleet.plan_shards();
        assert_eq!(plan.len(), 3);
        assert!(plan.iter().all(|&s| s >= 1));
        assert_eq!(plan[2], 1, "sub-threshold env must stay single-shard");
        assert!(plan[0] >= plan[1], "bigger env gets at least as many shards");
        // one-thread budget: everything single-shard
        fleet.set_threads(1);
        assert_eq!(fleet.plan_shards(), vec![1, 1, 1]);
    }

    #[test]
    fn coupling_groups_collect_families_by_feeder() {
        let fleet = Fleet::from_spec(&FleetSpec::demo(5, 1), None).unwrap();
        assert!(!fleet.has_coupling());
        assert!(fleet.coupling_groups().is_empty());
        assert!((0..fleet.n_envs()).all(|e| fleet.grid(e).is_none()));

        let fleet = Fleet::from_spec(&FleetSpec::demo_coupled(5, 1), None).unwrap();
        assert!(fleet.has_coupling());
        let groups = fleet.coupling_groups();
        assert_eq!(groups.len(), 1, "demo_coupled shares one feeder");
        let (spec, members) = &groups[0];
        assert_eq!(spec.feeder, "metro-west");
        assert_eq!(members, &vec![0, 1, 2]);
        assert!((0..3).all(|e| fleet.env(e).cfg.grid_coupled));
        // Hand-built fleets are never coupled.
        let hand = Fleet::from_envs(vec![tiny_env(8, 1)], vec!["x".into()]).unwrap();
        assert!(!hand.has_coupling());
    }

    #[test]
    fn from_spec_builds_demo_fleet() {
        let fleet = Fleet::from_spec(&FleetSpec::demo(5, 1), None).unwrap();
        assert_eq!(fleet.n_envs(), 3);
        assert_eq!(fleet.total_lanes(), 20);
        // Heterogeneous action/obs spaces across families.
        let d0 = fleet.env(0).obs_dim();
        let d1 = fleet.env(1).obs_dim();
        assert_ne!(d0, d1);
        assert!(fleet.env(1).cfg.v2g);
        assert_eq!(
            fleet.env(1).action_nvec()[0],
            crate::env::core::N_LEVELS_V2G,
            "V2G family exposes the signed car ladder"
        );
    }
}
