//! API-compatible stub of the `xla` PJRT bindings.
//!
//! The offline build image carries no native XLA/PJRT libraries, so this
//! crate provides the exact surface the coordinator uses — `Literal` is a
//! real host-side container (build/reshape/read back works), while anything
//! that would need a device runtime (`PjRtClient::cpu`, compilation,
//! execution, HLO parsing) returns a descriptive error. The coordinator
//! degrades gracefully: the native-vector fast path and all CPU comparators
//! run without PJRT, and swapping this path dependency for the real
//! bindings re-enables the AOT fast path with no source changes.

use std::borrow::Borrow;
use std::fmt;

#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT/XLA runtime unavailable (in-tree stub build; point the `xla` \
         path dependency at the real bindings to enable the AOT fast path)"
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
    U64,
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    U64(Vec<u64>),
    Tuple(Vec<Literal>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
            Data::U64(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    fn ty(&self) -> Option<ElementType> {
        match self {
            Data::F32(_) => Some(ElementType::F32),
            Data::I32(_) => Some(ElementType::S32),
            Data::U32(_) => Some(ElementType::U32),
            Data::U64(_) => Some(ElementType::U64),
            Data::Tuple(_) => None,
        }
    }
}

/// Host-side literal: flat typed data + dims. Fully functional.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Element types a `Literal` can hold.
pub trait NativeType: Clone + Sized {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($t:ty, $variant:ident) => {
        impl NativeType for $t {
            fn wrap(v: Vec<Self>) -> Data {
                Data::$variant(v)
            }
            fn unwrap(d: &Data) -> Option<Vec<Self>> {
                match d {
                    Data::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(i32, I32);
native!(u32, U32);
native!(u64, U64);

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            data: T::wrap(v.to_vec()),
        }
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            dims: vec![parts.len() as i64],
            data: Data::Tuple(parts),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product::<i64>().max(1);
        if want as usize != self.data.len().max(1) {
            return Err(XlaError(format!(
                "reshape to {dims:?} wants {want} elements, literal has {}",
                self.data.len()
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn shape(&self) -> Result<ArrayShape> {
        self.array_shape()
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data.ty() {
            Some(ty) => Ok(ArrayShape { dims: self.dims.clone(), ty }),
            None => Err(XlaError("tuple literal has no array shape".into())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| XlaError("literal element type mismatch in to_vec".into()))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(parts) => Ok(parts),
            _ => Err(XlaError("literal is not a tuple".into())),
        }
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path})")))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        let s = r.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2u32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[0f32]).to_tuple().is_err());
    }

    #[test]
    fn runtime_paths_fail_descriptively() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("/nope.hlo").is_err());
    }
}
