//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! The build is fully offline, so this vendored shim provides the subset of
//! the real API the workspace uses: `Error`, `Result`, `anyhow!`, `bail!`,
//! `ensure!`, and the `Context` extension trait on `Result`/`Option`.
//! Context frames chain outermost-first; `{:#}` formatting prints the full
//! chain like `anyhow` does.

use std::fmt;

/// Error: an outermost message plus the chain of underlying causes.
pub struct Error {
    /// chain[0] is the outermost context; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    pub fn new(msg: String) -> Error {
        Error { chain: vec![msg] }
    }

    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error::new(msg.to_string())
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Sealed conversion into [`Error`]: implemented for `Error` itself and for
/// every `std::error::Error`. (Mirrors anyhow's internal `StdError` trick;
/// coherence is fine because `Error` is local and does not implement
/// `std::error::Error`.)
#[doc(hidden)]
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::new(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::new(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::new(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::new(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("outer");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: gone");
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.context("missing thing");
        assert_eq!(format!("{}", r.unwrap_err()), "missing thing");
        let ok: Result<i32> = Some(3).context("unused");
        assert_eq!(ok.unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        let x = 7;
        let e = anyhow!("value {x} bad");
        assert_eq!(format!("{e}"), "value 7 bad");
        let e2 = anyhow!("{} and {}", 1, 2);
        assert_eq!(format!("{e2}"), "1 and 2");

        fn f(flag: bool) -> Result<i32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert!(f(true).is_ok());
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_anyhow_error_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
